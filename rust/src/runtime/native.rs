//! Pure-rust reference backend.
//!
//! Semantics mirror `python/compile/kernels/ref.py` bit-for-bit where
//! possible (f32 accumulation for sums to match the kernels' f32 math —
//! important so the HLO-vs-native integration tests can use tight
//! tolerances). Accepts any block length.

use crate::error::Result;
use crate::runtime::backend::AnalysisBackend;
use crate::util::stats::{fold_stats_f32, DistancePartial, Moments};

/// The no-artifacts execution engine (baseline + test oracle).
#[derive(Clone, Copy, Debug, Default)]
pub struct NativeBackend;

const HIST_BINS: usize = 64;

fn clamp_range(len: usize, start: usize, end: usize) -> (usize, usize) {
    let end = end.min(len);
    (start.min(end), end)
}

impl AnalysisBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn block_rows(&self) -> Option<usize> {
        None
    }

    fn segment_stats(&self, block: &[f32], start: usize, end: usize) -> Result<Moments> {
        let (start, end) = clamp_range(block.len(), start, end);
        // f32 partial sums (like the kernel), widened at the partial
        // level, accumulated in 8 independent lanes so the fold pipelines
        // instead of serializing on one accumulator — the shared
        // `fold_stats_f32`, which is also how seal-time aggregate sketches
        // are computed, so sketch partials are bit-identical to this scan.
        // NaNs are counted out (the crate-wide NaN policy, DESIGN.md §10).
        let (mx, mn, sum, sumsq, nans) = fold_stats_f32(&block[start..end]);
        let mut m =
            Moments::from_kernel(mx, mn, sum, sumsq, (end - start - nans) as f32);
        m.nans = nans as f64;
        Ok(m)
    }

    fn moving_average(
        &self,
        block: &[f32],
        start: usize,
        end: usize,
        window: usize,
    ) -> Result<Vec<f32>> {
        let (start, end) = clamp_range(block.len(), start, end);
        let mut out = vec![0f32; block.len()];
        if window == 0 || end - start < window {
            return Ok(out);
        }
        // Rolling sum over the selection (cumsum-style, matching kernel).
        let mut acc = 0f32;
        for i in start..end {
            acc += block[i];
            if i >= start + window {
                acc -= block[i - window];
            }
            if i >= start + window - 1 {
                out[i] = acc / window as f32;
            }
        }
        Ok(out)
    }

    fn ma_stats(
        &self,
        block: &[f32],
        start: usize,
        end: usize,
        window: usize,
    ) -> Result<Moments> {
        let ma = self.moving_average(block, start, end, window)?;
        let (start, end) = clamp_range(block.len(), start, end);
        let s = (start + window.saturating_sub(1)).min(end);
        self.segment_stats(&ma, s, end)
    }

    fn distance(
        &self,
        a: &[f32],
        b: &[f32],
        start: usize,
        end: usize,
    ) -> Result<DistancePartial> {
        debug_assert_eq!(a.len(), b.len());
        let (start, end) = clamp_range(a.len().min(b.len()), start, end);
        let mut l1 = 0f32;
        let mut l2sq = 0f32;
        let mut linf = 0f32;
        let mut nans = 0usize;
        for i in start..end {
            let d = a[i] - b[i];
            if d.is_nan() {
                nans += 1;
                continue;
            }
            let ad = d.abs();
            l1 += ad;
            l2sq += d * d;
            linf = linf.max(ad);
        }
        let mut p =
            DistancePartial::from_kernel(l1, l2sq, linf, (end - start - nans) as f32);
        p.nans = nans as f64;
        Ok(p)
    }

    fn histogram64(
        &self,
        block: &[f32],
        start: usize,
        end: usize,
        lo: f32,
        hi: f32,
    ) -> Result<Vec<f32>> {
        let (start, end) = clamp_range(block.len(), start, end);
        let width = (hi - lo) / HIST_BINS as f32;
        let mut bins = vec![0f32; HIST_BINS];
        for &x in &block[start..end] {
            // NaNs are skipped (they used to alias to bin 0 via the cast);
            // out-of-range values land in the edge bins, like the kernel.
            if x.is_nan() {
                continue;
            }
            let raw = ((x - lo) / width) as i64;
            let b = raw.clamp(0, HIST_BINS as i64 - 1) as usize;
            bins[b] += 1.0;
        }
        Ok(bins)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn backend() -> NativeBackend {
        NativeBackend
    }

    #[test]
    fn stats_basic() {
        let b = backend();
        let m = b.segment_stats(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0], 0, 8).unwrap();
        assert_eq!(m.mean(), 5.0);
        assert!((m.std() - 2.0).abs() < 1e-6);
        assert_eq!(m.max, 9.0);
        assert_eq!(m.min, 2.0);
    }

    #[test]
    fn stats_empty_range_sentinels() {
        let m = backend().segment_stats(&[1.0; 8], 3, 3).unwrap();
        assert!(m.is_empty());
        assert!(m.max < -1e38 && m.min > 1e38);
    }

    #[test]
    fn stats_range_clamped() {
        let m = backend().segment_stats(&[1.0; 8], 4, 100).unwrap();
        assert_eq!(m.count, 4.0);
    }

    #[test]
    fn ma_matches_naive() {
        let mut rng = Xoshiro256::seeded(5);
        let xs: Vec<f32> = (0..256).map(|_| rng.next_f32() * 10.0).collect();
        let (s, e, w) = (13, 201, 16);
        let got = backend().moving_average(&xs, s, e, w).unwrap();
        for i in 0..xs.len() {
            let want = if i >= s + w - 1 && i < e {
                xs[i + 1 - w..=i].iter().sum::<f32>() / w as f32
            } else {
                0.0
            };
            assert!((got[i] - want).abs() < 1e-3, "i={i} got={} want={want}", got[i]);
        }
    }

    #[test]
    fn ma_window_bigger_than_selection() {
        let got = backend().moving_average(&[1.0; 10], 2, 5, 8).unwrap();
        assert!(got.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn ma_zero_window_all_zero() {
        let got = backend().moving_average(&[1.0; 4], 0, 4, 0).unwrap();
        assert_eq!(got, vec![0.0; 4]);
    }

    #[test]
    fn ma_stats_matches_composition() {
        let xs: Vec<f32> = (0..128).map(|i| (i as f32).cos()).collect();
        let b = backend();
        let fused = b.ma_stats(&xs, 8, 120, 4).unwrap();
        let ma = b.moving_average(&xs, 8, 120, 4).unwrap();
        let composed = b.segment_stats(&ma, 11, 120).unwrap();
        assert_eq!(fused, composed);
    }

    #[test]
    fn distance_basic() {
        let a = [0f32; 64];
        let b = [1f32; 64];
        let d = backend().distance(&a, &b, 16, 48, ).unwrap();
        assert_eq!(d.l1, 32.0);
        assert_eq!(d.l2sq, 32.0);
        assert_eq!(d.linf, 1.0);
        assert_eq!(d.count, 32.0);
    }

    #[test]
    fn distance_identical_zero() {
        let a: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let d = backend().distance(&a, &a, 0, 100).unwrap();
        assert_eq!((d.l1, d.l2sq, d.linf as f64), (0.0, 0.0, 0.0));
    }

    #[test]
    fn histogram_mass_and_edges() {
        let mut xs = vec![0.5f32; 90];
        xs.extend([-5.0f32, 5.0]);
        let h = backend().histogram64(&xs, 0, 92, 0.0, 1.0).unwrap();
        assert_eq!(h.iter().sum::<f32>(), 92.0);
        assert_eq!(h[32], 90.0); // 0.5 → bin 32
        assert_eq!(h[0], 1.0); // clamped low
        assert_eq!(h[63], 1.0); // clamped high
    }

    #[test]
    fn nan_policy_stats_distance_histogram() {
        let b = backend();
        // Stats: NaNs excluded from every moment, counted separately.
        let m = b
            .segment_stats(&[2.0, f32::NAN, 4.0, f32::NAN, 9.0], 0, 5)
            .unwrap();
        assert_eq!(m.count, 3.0);
        assert_eq!(m.nans, 2.0);
        assert_eq!(m.max, 9.0);
        assert_eq!(m.min, 2.0);
        assert_eq!(m.mean(), 5.0);
        assert!(m.std().is_finite());

        // Distance: a NaN on either side drops the pair, not the total.
        let x = [1.0, f32::NAN, 3.0, 4.0];
        let y = [1.0, 2.0, f32::NAN, 5.0];
        let d = b.distance(&x, &y, 0, 4).unwrap();
        assert_eq!(d.count, 2.0);
        assert_eq!(d.nans, 2.0);
        assert_eq!(d.l1, 1.0);
        assert!(d.l2sq.is_finite());

        // Histogram: NaN is skipped, not aliased into bin 0.
        let h = b
            .histogram64(&[0.5, f32::NAN, 0.5], 0, 3, 0.0, 1.0)
            .unwrap();
        assert_eq!(h.iter().sum::<f32>(), 2.0);
        assert_eq!(h[0], 0.0);
    }

    #[test]
    fn lane_fold_matches_scan_oracle() {
        // The 8-lane segment_stats must agree with the f64 `Moments::scan`
        // oracle: exactly on count/nans/max/min (order-free folds), and
        // exactly on the sums for integer-valued data (no rounding in any
        // association); within tolerance on random data (f32 lane sums
        // regroup the additions).
        let b = backend();
        let ints: Vec<f32> = (0..4096).map(|i| ((i * 31) % 1000) as f32).collect();
        let got = b.segment_stats(&ints, 0, 4096).unwrap();
        let want = Moments::scan(&ints);
        assert_eq!(got.count, want.count);
        assert_eq!(got.max, want.max);
        assert_eq!(got.min, want.min);
        assert_eq!(got.sum, want.sum);

        let mut rng = Xoshiro256::seeded(99);
        let mut xs: Vec<f32> =
            (0..4096).map(|_| (rng.next_f32() - 0.5) * 200.0).collect();
        for i in (0..4096).step_by(513) {
            xs[i] = f32::NAN;
        }
        for (s, e) in [(0usize, 4096usize), (17, 4000), (100, 101), (5, 5)] {
            let got = b.segment_stats(&xs, s, e).unwrap();
            let want = Moments::scan(&xs[s..e]);
            assert_eq!(got.count, want.count, "[{s},{e})");
            assert_eq!(got.nans, want.nans, "[{s},{e})");
            assert_eq!(got.max, want.max);
            assert_eq!(got.min, want.min);
            if want.count > 0.0 {
                assert!(
                    (got.mean() - want.mean()).abs() < 1e-3,
                    "[{s},{e}): {} vs {}",
                    got.mean(),
                    want.mean()
                );
                assert!((got.std() - want.std()).abs() < 1e-2);
            }
        }
    }

    #[test]
    fn batch_matches_singles() {
        let b = backend();
        let x: Vec<f32> = (0..64).map(|i| i as f32).collect();
        let y: Vec<f32> = (0..64).map(|i| (64 - i) as f32).collect();
        let batch = b
            .segment_stats_batch(&[(&x, 0, 64), (&y, 10, 20)])
            .unwrap();
        assert_eq!(batch[0], b.segment_stats(&x, 0, 64).unwrap());
        assert_eq!(batch[1], b.segment_stats(&y, 10, 20).unwrap());
    }
}
