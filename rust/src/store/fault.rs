//! Failpoint layer and the [`StoreIo`] filesystem wrapper.
//!
//! Every filesystem touch in `store/` goes through [`StoreIo`] (enforced by
//! the `store-io-wrapped` lint rule), so one seeded [`FaultInjector`] can
//! deterministically inject I/O errors, torn writes, bit-flips and latency
//! at named **sites** — and simulate a whole-process crash at the Nth
//! mutating operation. When no injector is attached every primitive
//! compiles down to the plain `std::fs` call plus one `Option` check:
//! zero-cost in production.
//!
//! The injector is configured programmatically (tests, benches) or from the
//! environment: `OSEBA_FAULTS="site=kind[:budget][@prob],…"` with kinds
//! `error`, `torn`, `bitflip` and `delay<ms>`, seeded by `OSEBA_FAULT_SEED`.
//! `site` may be `*` to match every site.
//!
//! Crash simulation: [`FaultInjector::arm_crash_after`]`(n)` makes the
//! n-th subsequent *mutating* primitive (write, rename, remove, dir sync)
//! fail — a data write tears, leaving a half-written file, exactly like a
//! real power cut — and every later mutating primitive fails too. Reads
//! keep working, so a test can inspect the "disk" the crash left behind
//! before re-opening it with a clean [`StoreIo`].
//!
//! [`RetryPolicy`] (bounded exponential backoff) lives here too: it is the
//! knob [`TieredStore`](crate::store::TieredStore) uses to retry transient
//! fault-in I/O before quarantining a partition (DESIGN.md §16).

use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::error::{OsebaError, Result};
use crate::util::rng::Xoshiro256;
use crate::util::sync::MutexExt;

/// Named failpoint sites — the vocabulary `OSEBA_FAULTS` rules target.
pub mod site {
    /// Segment commit: tmp write + rename + directory sync.
    pub const SEGMENT_WRITE: &str = "segment.write";
    /// Segment fault-in read.
    pub const SEGMENT_READ: &str = "segment.read";
    /// Manifest commit: `.prev` copy + tmp write + rename + directory sync.
    pub const MANIFEST_WRITE: &str = "manifest.write";
    /// Manifest load.
    pub const MANIFEST_READ: &str = "manifest.read";
    /// Store-directory maintenance: create, stale-file removal, the
    /// open-time recovery scan.
    pub const DIR_MAINTENANCE: &str = "dir.maintenance";
}

/// What an armed failpoint does when it fires.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    /// The primitive fails with an injected `io::Error` (transient class —
    /// the retry layer may recover it).
    Error,
    /// A write persists only a prefix of its bytes, then errors — a torn
    /// write. On non-data mutations (rename, sync) this degrades to
    /// [`FaultKind::Error`].
    Torn,
    /// A read returns its bytes with exactly one bit flipped at a seeded
    /// position — the CRC layer must catch it. No error is reported.
    BitFlip,
    /// The primitive sleeps this many milliseconds, then proceeds.
    Delay(u64),
}

/// One armed failpoint: `kind` fires at `site` while `budget` lasts, each
/// opportunity gated by `prob`.
#[derive(Clone, Debug)]
pub struct FaultRule {
    /// Site the rule matches ([`site`] constant, or `*` for every site).
    pub site: String,
    /// Behavior when the rule fires.
    pub kind: FaultKind,
    /// Remaining firings (`usize::MAX` = unlimited).
    pub budget: usize,
    /// Probability in `[0, 1]` that a matching opportunity fires.
    pub prob: f64,
}

impl FaultRule {
    /// An unlimited, always-firing rule for `kind` at `site`.
    pub fn new(site: &str, kind: FaultKind) -> FaultRule {
        FaultRule { site: site.to_string(), kind, budget: usize::MAX, prob: 1.0 }
    }

    /// Cap the rule to fire at most `n` times.
    pub fn budget(mut self, n: usize) -> FaultRule {
        self.budget = n;
        self
    }

    /// Gate each opportunity on probability `p`.
    pub fn prob(mut self, p: f64) -> FaultRule {
        self.prob = p;
        self
    }
}

/// Deterministic fault source shared by every [`StoreIo`] clone of a store.
///
/// See the [module docs](self) for the rule grammar and crash semantics.
#[derive(Debug)]
pub struct FaultInjector {
    rules: Mutex<Vec<FaultRule>>,
    rng: Mutex<Xoshiro256>,
    /// Mutating-primitive counter (monotonic across the injector's life).
    ops: AtomicUsize,
    /// Absolute op index that triggers the simulated crash
    /// (`usize::MAX` = disarmed).
    crash_at: AtomicUsize,
    crashed: AtomicBool,
}

impl FaultInjector {
    /// An injector with no rules and no crash point, seeded for any
    /// probabilistic rules added later.
    pub fn new(seed: u64) -> FaultInjector {
        FaultInjector {
            rules: Mutex::new(Vec::new()),
            rng: Mutex::new(Xoshiro256::seeded(seed)),
            ops: AtomicUsize::new(0),
            crash_at: AtomicUsize::new(usize::MAX),
            crashed: AtomicBool::new(false),
        }
    }

    /// Parse a comma-separated `site=kind[:budget][@prob]` spec (the
    /// `OSEBA_FAULTS` grammar) into an injector seeded with `seed`.
    pub fn from_spec(spec: &str, seed: u64) -> Result<FaultInjector> {
        let inj = FaultInjector::new(seed);
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            inj.add_rule(parse_rule(part)?);
        }
        Ok(inj)
    }

    /// Arm another failpoint rule.
    pub fn add_rule(&self, rule: FaultRule) {
        self.rules.lock_recover().push(rule);
    }

    /// Drop every armed rule (the crash point is untouched).
    pub fn clear_rules(&self) {
        self.rules.lock_recover().clear();
    }

    /// Simulate a crash at the `n`-th mutating primitive from now
    /// (0 = the very next one). The crashing write tears; everything
    /// mutating after it fails until [`FaultInjector::disarm_crash`].
    pub fn arm_crash_after(&self, n: usize) {
        self.crashed.store(false, Ordering::SeqCst);
        let now = self.ops.load(Ordering::SeqCst);
        self.crash_at.store(now.saturating_add(n), Ordering::SeqCst);
    }

    /// Disarm the crash point and clear the crashed latch.
    pub fn disarm_crash(&self) {
        self.crash_at.store(usize::MAX, Ordering::SeqCst);
        self.crashed.store(false, Ordering::SeqCst);
    }

    /// Whether the simulated crash has triggered.
    pub fn crashed(&self) -> bool {
        self.crashed.load(Ordering::SeqCst)
    }

    /// Mutating primitives observed so far.
    pub fn mutations(&self) -> usize {
        self.ops.load(Ordering::SeqCst)
    }

    /// Pop the first matching armed rule's kind, honoring budget and
    /// probability.
    fn fire(&self, at: &str) -> Option<FaultKind> {
        let mut rules = self.rules.lock_recover();
        let rule = rules
            .iter_mut()
            .find(|r| r.budget > 0 && (r.site == "*" || r.site == at))?;
        if rule.prob < 1.0 && self.rng.lock_recover().next_f64() >= rule.prob {
            return None;
        }
        if rule.budget != usize::MAX {
            rule.budget -= 1;
        }
        Some(rule.kind)
    }

    /// Decision for a mutating primitive at `at` — counts the op, applies
    /// the crash point, then the rules.
    fn mutation_fault(&self, at: &str) -> WriteFault {
        if self.crashed.load(Ordering::SeqCst) {
            return WriteFault::Error;
        }
        let op = self.ops.fetch_add(1, Ordering::SeqCst);
        if op == self.crash_at.load(Ordering::SeqCst) {
            self.crashed.store(true, Ordering::SeqCst);
            return WriteFault::Torn;
        }
        match self.fire(at) {
            Some(FaultKind::Error) => WriteFault::Error,
            Some(FaultKind::Torn) => WriteFault::Torn,
            Some(FaultKind::Delay(ms)) => {
                std::thread::sleep(Duration::from_millis(ms));
                WriteFault::None
            }
            Some(FaultKind::BitFlip) | None => WriteFault::None,
        }
    }

    /// Decision for a read primitive at `at`. Reads are not mutations:
    /// they neither count toward nor suffer the crash point, so a test can
    /// inspect the post-crash "disk".
    fn read_fault(&self, at: &str) -> ReadFault {
        match self.fire(at) {
            Some(FaultKind::Error) => ReadFault::Error,
            Some(FaultKind::BitFlip) => {
                ReadFault::Flip(self.rng.lock_recover().next_u64())
            }
            Some(FaultKind::Delay(ms)) => {
                std::thread::sleep(Duration::from_millis(ms));
                ReadFault::None
            }
            Some(FaultKind::Torn) | None => ReadFault::None,
        }
    }
}

enum WriteFault {
    None,
    Error,
    Torn,
}

enum ReadFault {
    None,
    Error,
    /// Raw entropy the flip position is derived from.
    Flip(u64),
}

/// The injected-error payload — recognizable in messages and, as an
/// `io::Error`, classified transient by the retry layer.
fn injected(at: &str) -> std::io::Error {
    std::io::Error::other(format!("injected fault at {at}"))
}

/// fsync a directory so a rename within it is durable. Under Miri the
/// directory open is a no-op (Miri has no dirfd fsync shim); the commit
/// protocol around it is exercised natively and under the fault battery.
fn fsync_dir(dir: &Path) -> std::io::Result<()> {
    #[cfg(not(miri))]
    {
        std::fs::File::open(dir)?.sync_all()
    }
    #[cfg(miri)]
    {
        let _ = dir;
        Ok(())
    }
}

/// The only doorway from `store/` to the filesystem.
///
/// Cloning is cheap; clones share the same injector (or share "disabled").
/// Primitives return [`OsebaError::Io`] naming the path, like the raw
/// `std::fs` calls they replace.
#[derive(Clone, Debug, Default)]
pub struct StoreIo {
    injector: Option<Arc<FaultInjector>>,
}

impl StoreIo {
    /// Plain passthrough I/O — the production configuration.
    pub fn disabled() -> StoreIo {
        StoreIo { injector: None }
    }

    /// I/O filtered through `injector`.
    pub fn with(injector: Arc<FaultInjector>) -> StoreIo {
        StoreIo { injector: Some(injector) }
    }

    /// Build from `OSEBA_FAULTS` / `OSEBA_FAULT_SEED` (disabled when
    /// `OSEBA_FAULTS` is unset or empty). A malformed spec is a
    /// [`OsebaError::Config`] — better a loud failure than silently
    /// running a resilience experiment with no faults armed.
    pub fn from_env() -> Result<StoreIo> {
        let spec = match std::env::var("OSEBA_FAULTS") {
            Ok(s) if !s.trim().is_empty() => s,
            _ => return Ok(StoreIo::disabled()),
        };
        let seed = match std::env::var("OSEBA_FAULT_SEED") {
            Ok(s) => s.trim().parse::<u64>().map_err(|_| {
                OsebaError::Config(format!("OSEBA_FAULT_SEED '{s}' is not a u64"))
            })?,
            Err(_) => 0,
        };
        Ok(StoreIo::with(Arc::new(FaultInjector::from_spec(&spec, seed)?)))
    }

    /// The attached injector, if any (tests and benches reach through to
    /// arm crash points mid-scenario).
    pub fn injector(&self) -> Option<&Arc<FaultInjector>> {
        self.injector.as_ref()
    }

    /// Read a whole file.
    pub fn read(&self, at: &str, path: impl AsRef<Path>) -> Result<Vec<u8>> {
        let path = path.as_ref();
        let fault = match &self.injector {
            Some(inj) => inj.read_fault(at),
            None => ReadFault::None,
        };
        if let ReadFault::Error = fault {
            return Err(OsebaError::io(path, injected(at)));
        }
        let mut bytes = std::fs::read(path).map_err(|e| OsebaError::io(path, e))?;
        if let ReadFault::Flip(entropy) = fault {
            if !bytes.is_empty() {
                let bit = entropy as usize % (bytes.len() * 8);
                bytes[bit / 8] ^= 1 << (bit % 8);
            }
        }
        Ok(bytes)
    }

    /// Read a whole file as UTF-8.
    pub fn read_to_string(&self, at: &str, path: impl AsRef<Path>) -> Result<String> {
        let bytes = self.read(at, &path)?;
        String::from_utf8(bytes).map_err(|e| {
            OsebaError::Store(format!(
                "file '{}' is not UTF-8: {e}",
                path.as_ref().display()
            ))
        })
    }

    /// Create/truncate `path`, write `bytes`, and fsync the file. A torn
    /// fault (or the crash point) persists only a prefix — exactly the
    /// state a real crash mid-write leaves behind.
    pub fn write_durable(&self, at: &str, path: impl AsRef<Path>, bytes: &[u8]) -> Result<()> {
        let path = path.as_ref();
        if let Some(inj) = &self.injector {
            match inj.mutation_fault(at) {
                WriteFault::None => {}
                WriteFault::Error => return Err(OsebaError::io(path, injected(at))),
                WriteFault::Torn => {
                    let _ = std::fs::write(path, &bytes[..bytes.len() / 2]);
                    return Err(OsebaError::io(path, injected(at)));
                }
            }
        }
        let mut f = std::fs::File::create(path).map_err(|e| OsebaError::io(path, e))?;
        f.write_all(bytes).map_err(|e| OsebaError::io(path, e))?;
        f.sync_all().map_err(|e| OsebaError::io(path, e))?;
        Ok(())
    }

    /// Atomically rename `from` to `to` (same directory).
    pub fn rename(&self, at: &str, from: impl AsRef<Path>, to: impl AsRef<Path>) -> Result<()> {
        let (from, to) = (from.as_ref(), to.as_ref());
        if let Some(inj) = &self.injector {
            match inj.mutation_fault(at) {
                WriteFault::None => {}
                // Renames are atomic: torn degrades to not-performed.
                WriteFault::Error | WriteFault::Torn => {
                    return Err(OsebaError::io(to, injected(at)))
                }
            }
        }
        std::fs::rename(from, to).map_err(|e| OsebaError::io(to, e))
    }

    /// fsync `dir`, making renames/creates within it durable.
    pub fn sync_dir(&self, at: &str, dir: impl AsRef<Path>) -> Result<()> {
        let dir = dir.as_ref();
        if let Some(inj) = &self.injector {
            match inj.mutation_fault(at) {
                WriteFault::None => {}
                WriteFault::Error | WriteFault::Torn => {
                    return Err(OsebaError::io(dir, injected(at)))
                }
            }
        }
        fsync_dir(dir).map_err(|e| OsebaError::io(dir, e))
    }

    /// Remove a file.
    pub fn remove_file(&self, at: &str, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(inj) = &self.injector {
            match inj.mutation_fault(at) {
                WriteFault::None => {}
                WriteFault::Error | WriteFault::Torn => {
                    return Err(OsebaError::io(path, injected(at)))
                }
            }
        }
        std::fs::remove_file(path).map_err(|e| OsebaError::io(path, e))
    }

    /// Create `dir` and any missing parents.
    pub fn create_dir_all(&self, at: &str, dir: impl AsRef<Path>) -> Result<()> {
        let dir = dir.as_ref();
        if let Some(inj) = &self.injector {
            match inj.mutation_fault(at) {
                WriteFault::None => {}
                WriteFault::Error | WriteFault::Torn => {
                    return Err(OsebaError::io(dir, injected(at)))
                }
            }
        }
        std::fs::create_dir_all(dir).map_err(|e| OsebaError::io(dir, e))
    }

    /// List the plain file names in `dir` (lossy UTF-8, unsorted).
    pub fn read_dir(&self, at: &str, dir: impl AsRef<Path>) -> Result<Vec<String>> {
        let dir = dir.as_ref();
        if let Some(inj) = &self.injector {
            if let ReadFault::Error = inj.read_fault(at) {
                return Err(OsebaError::io(dir, injected(at)));
            }
        }
        let entries = std::fs::read_dir(dir).map_err(|e| OsebaError::io(dir, e))?;
        let mut names = Vec::new();
        for entry in entries {
            let entry = entry.map_err(|e| OsebaError::io(dir, e))?;
            if entry.file_type().map(|t| t.is_file()).unwrap_or(false) {
                names.push(entry.file_name().to_string_lossy().into_owned());
            }
        }
        Ok(names)
    }

    /// Whether `path` exists — pure inspection, never injected.
    pub fn exists(&self, path: impl AsRef<Path>) -> bool {
        path.as_ref().exists()
    }

    /// The crash-safe commit protocol for one file: durably write
    /// `<path>.tmp`, rename it over `path`, then fsync the directory. A
    /// crash at any point leaves either the old `path` (plus at most an
    /// orphaned `.tmp` for the recovery scan) or the fully-committed new
    /// one — never a torn `path`.
    pub fn commit(&self, at: &str, path: impl AsRef<Path>, bytes: &[u8]) -> Result<()> {
        let path = path.as_ref();
        let tmp = tmp_path(path);
        self.write_durable(at, &tmp, bytes)?;
        self.rename(at, &tmp, path)?;
        match path.parent() {
            Some(dir) if !dir.as_os_str().is_empty() => self.sync_dir(at, dir),
            _ => Ok(()),
        }
    }
}

/// `<path>.tmp` — the commit protocol's staging name.
pub(crate) fn tmp_path(path: &Path) -> PathBuf {
    let mut s = path.as_os_str().to_os_string();
    s.push(".tmp");
    PathBuf::from(s)
}

/// Bounded exponential backoff for transient fault-in I/O.
///
/// Attempt `k` (0-based) sleeps `min(base_delay << k, max_delay)` before
/// retrying; after `max_attempts` total attempts the last error stands.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Total attempts, including the first (`1` = no retries).
    pub max_attempts: usize,
    /// Backoff before the first retry.
    pub base_delay: Duration,
    /// Backoff ceiling.
    pub max_delay: Duration,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 3,
            base_delay: Duration::from_millis(1),
            max_delay: Duration::from_millis(50),
        }
    }
}

impl RetryPolicy {
    /// Fail-fast policy: one attempt, no backoff.
    pub fn none() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 1,
            base_delay: Duration::ZERO,
            max_delay: Duration::ZERO,
        }
    }

    /// Backoff before retry number `retry` (0-based): exponential from
    /// `base_delay`, capped at `max_delay`.
    pub fn backoff(&self, retry: usize) -> Duration {
        let factor = 1u32 << retry.min(31) as u32;
        self.base_delay.saturating_mul(factor).min(self.max_delay)
    }
}

/// Parse one `site=kind[:budget][@prob]` rule.
fn parse_rule(part: &str) -> Result<FaultRule> {
    let bad = |why: &str| OsebaError::Config(format!("fault rule '{part}': {why}"));
    let (at, mut spec) = part
        .split_once('=')
        .ok_or_else(|| bad("expected site=kind[:budget][@prob]"))?;
    let mut prob = 1.0;
    if let Some((head, p)) = spec.split_once('@') {
        prob = p
            .parse::<f64>()
            .ok()
            .filter(|p| (0.0..=1.0).contains(p))
            .ok_or_else(|| bad("probability must be a float in [0, 1]"))?;
        spec = head;
    }
    let mut budget = usize::MAX;
    if let Some((head, b)) = spec.split_once(':') {
        budget = b.parse::<usize>().map_err(|_| bad("budget must be a usize"))?;
        spec = head;
    }
    let kind = match spec {
        "error" => FaultKind::Error,
        "torn" => FaultKind::Torn,
        "bitflip" => FaultKind::BitFlip,
        d if d.starts_with("delay") => {
            let ms = d["delay".len()..]
                .parse::<u64>()
                .map_err(|_| bad("delay needs milliseconds, e.g. delay10"))?;
            FaultKind::Delay(ms)
        }
        _ => return Err(bad("kind must be error|torn|bitflip|delay<ms>")),
    };
    Ok(FaultRule { site: at.trim().to_string(), kind, budget, prob })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::temp_dir;

    #[test]
    fn disabled_io_round_trips_bytes() {
        let dir = temp_dir("fault-off");
        let io = StoreIo::disabled();
        let path = dir.join("blob");
        io.write_durable(site::SEGMENT_WRITE, &path, b"hello").unwrap();
        assert_eq!(io.read(site::SEGMENT_READ, &path).unwrap(), b"hello");
        assert!(io.exists(&path));
        io.remove_file(site::DIR_MAINTENANCE, &path).unwrap();
        assert!(!io.exists(&path));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn spec_parses_budget_and_prob() {
        let inj =
            FaultInjector::from_spec("segment.read=error:2, manifest.write=torn@0.5", 1).unwrap();
        let rules = inj.rules.lock_recover();
        assert_eq!(rules.len(), 2);
        assert_eq!(rules[0].kind, FaultKind::Error);
        assert_eq!(rules[0].budget, 2);
        assert_eq!(rules[1].kind, FaultKind::Torn);
        assert!((rules[1].prob - 0.5).abs() < 1e-12);
        drop(rules);
        let inj = FaultInjector::from_spec("*=delay7:1@0.25", 1).unwrap();
        let rules = inj.rules.lock_recover();
        assert_eq!(rules[0].site, "*");
        assert_eq!(rules[0].kind, FaultKind::Delay(7));
        assert_eq!(rules[0].budget, 1);
    }

    #[test]
    fn spec_rejects_garbage() {
        for bad in [
            "segment.read",             // no kind
            "segment.read=explode",     // unknown kind
            "segment.read=error:x",     // bad budget
            "segment.read=error@1.5",   // prob out of range
            "segment.read=delayfast",   // bad delay
        ] {
            assert!(
                matches!(FaultInjector::from_spec(bad, 0), Err(OsebaError::Config(_))),
                "spec '{bad}' should be rejected"
            );
        }
    }

    #[test]
    fn error_rule_budget_exhausts() {
        let dir = temp_dir("fault-budget");
        let path = dir.join("blob");
        std::fs::write(&path, b"data").unwrap();
        let inj = Arc::new(FaultInjector::new(0));
        inj.add_rule(FaultRule::new(site::SEGMENT_READ, FaultKind::Error).budget(2));
        let io = StoreIo::with(Arc::clone(&inj));
        assert!(io.read(site::SEGMENT_READ, &path).is_err());
        assert!(io.read(site::SEGMENT_READ, &path).is_err());
        assert_eq!(io.read(site::SEGMENT_READ, &path).unwrap(), b"data");
        // Rules are site-scoped: another site never fires this rule.
        inj.add_rule(FaultRule::new(site::SEGMENT_READ, FaultKind::Error).budget(1));
        assert_eq!(io.read(site::MANIFEST_READ, &path).unwrap(), b"data");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn bitflip_corrupts_exactly_one_bit() {
        let dir = temp_dir("fault-flip");
        let path = dir.join("blob");
        let bytes: Vec<u8> = (0..64u8).collect();
        std::fs::write(&path, &bytes).unwrap();
        let inj = Arc::new(FaultInjector::new(42));
        inj.add_rule(FaultRule::new(site::SEGMENT_READ, FaultKind::BitFlip).budget(1));
        let io = StoreIo::with(inj);
        let got = io.read(site::SEGMENT_READ, &path).unwrap();
        let diff: u32 = got
            .iter()
            .zip(&bytes)
            .map(|(a, b)| (a ^ b).count_ones())
            .sum();
        assert_eq!(diff, 1, "exactly one bit must differ");
        // Budget spent: the next read is clean.
        assert_eq!(io.read(site::SEGMENT_READ, &path).unwrap(), bytes);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn probability_is_seed_deterministic() {
        let decide = |seed: u64| -> Vec<bool> {
            let inj = FaultInjector::new(seed);
            inj.add_rule(FaultRule::new("*", FaultKind::Error).prob(0.5));
            (0..32).map(|_| inj.fire("x").is_some()).collect()
        };
        assert_eq!(decide(7), decide(7), "same seed, same firings");
        assert_ne!(decide(7), decide(8), "different seed, different firings");
        let fired = decide(7).iter().filter(|&&f| f).count();
        assert!((4..=28).contains(&fired), "p=0.5 fired {fired}/32");
    }

    #[test]
    fn crash_point_tears_then_halts_mutations() {
        let dir = temp_dir("fault-crash");
        let inj = Arc::new(FaultInjector::new(0));
        let io = StoreIo::with(Arc::clone(&inj));
        let a = dir.join("a");
        let b = dir.join("b");
        inj.arm_crash_after(1);
        io.write_durable(site::SEGMENT_WRITE, &a, b"aaaaaaaa").unwrap();
        // Second mutation is the crash: the write tears.
        assert!(io.write_durable(site::SEGMENT_WRITE, &b, b"bbbbbbbb").is_err());
        assert!(inj.crashed());
        assert_eq!(std::fs::read(&b).unwrap(), b"bbbb", "torn prefix persisted");
        // Every later mutation fails; reads still work.
        assert!(io.write_durable(site::SEGMENT_WRITE, &a, b"x").is_err());
        assert!(io.rename(site::SEGMENT_WRITE, &a, &b).is_err());
        assert_eq!(io.read(site::SEGMENT_READ, &a).unwrap(), b"aaaaaaaa");
        inj.disarm_crash();
        io.write_durable(site::SEGMENT_WRITE, &a, b"again").unwrap();
        assert!(!inj.crashed());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn commit_never_tears_the_final_path() {
        let dir = temp_dir("fault-commit");
        let path = dir.join("manifest.json");
        let io = StoreIo::disabled();
        io.commit(site::MANIFEST_WRITE, &path, b"v1").unwrap();
        assert_eq!(io.read(site::MANIFEST_READ, &path).unwrap(), b"v1");
        assert!(!io.exists(tmp_path(&path)), "commit cleans its tmp");

        let inj = Arc::new(FaultInjector::new(0));
        inj.add_rule(FaultRule::new(site::MANIFEST_WRITE, FaultKind::Torn).budget(1));
        let faulty = StoreIo::with(inj);
        assert!(faulty.commit(site::MANIFEST_WRITE, &path, b"v2-longer").is_err());
        // The torn write hit the tmp file; the committed path is intact.
        assert_eq!(io.read(site::MANIFEST_READ, &path).unwrap(), b"v1");
        assert!(io.exists(tmp_path(&path)), "torn tmp left for the recovery scan");
        // With the budget spent the same commit goes through.
        faulty.commit(site::MANIFEST_WRITE, &path, b"v2-longer").unwrap();
        assert_eq!(io.read(site::MANIFEST_READ, &path).unwrap(), b"v2-longer");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn read_dir_lists_plain_files() {
        let dir = temp_dir("fault-ls");
        let io = StoreIo::disabled();
        io.write_durable(site::SEGMENT_WRITE, dir.join("x.oseg"), b"x").unwrap();
        io.write_durable(site::SEGMENT_WRITE, dir.join("y.tmp"), b"y").unwrap();
        io.create_dir_all(site::DIR_MAINTENANCE, dir.join("sub")).unwrap();
        let mut names = io.read_dir(site::DIR_MAINTENANCE, &dir).unwrap();
        names.sort();
        assert_eq!(names, ["x.oseg", "y.tmp"], "directories are not files");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn retry_backoff_is_exponential_and_capped() {
        let p = RetryPolicy::default();
        assert_eq!(p.max_attempts, 3);
        assert_eq!(p.backoff(0), Duration::from_millis(1));
        assert_eq!(p.backoff(1), Duration::from_millis(2));
        assert_eq!(p.backoff(5), Duration::from_millis(32));
        assert_eq!(p.backoff(6), Duration::from_millis(50), "capped");
        assert_eq!(p.backoff(500), Duration::from_millis(50), "shift saturates");
        let none = RetryPolicy::none();
        assert_eq!(none.max_attempts, 1);
        assert_eq!(none.backoff(0), Duration::ZERO);
    }

    #[test]
    fn from_env_requires_well_formed_spec() {
        // No env manipulation here (tests run in parallel): exercise the
        // parser the env path delegates to.
        assert!(FaultInjector::from_spec("", 0).unwrap().rules.lock_recover().is_empty());
        assert!(FaultInjector::from_spec("segment.read=error", 0).is_ok());
        assert!(FaultInjector::from_spec("segment.read=?", 0).is_err());
    }
}
