//! Typed configuration for the engine, coordinator and benchmark driver,
//! plus a small key=value / TOML-subset file parser (no `serde` in the
//! vendored set) and CLI overrides.

mod parse;

pub use parse::{parse_config_text, ConfigMap};

use crate::error::{OsebaError, Result};

/// Engine-level configuration.
#[derive(Clone, Debug)]
pub struct ContextConfig {
    /// Worker threads for parallel partition scans.
    pub num_workers: usize,
    /// Optional storage-memory budget in bytes.
    pub memory_budget: Option<usize>,
}

impl Default for ContextConfig {
    fn default() -> Self {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        ContextConfig { num_workers: n.min(16), memory_budget: None }
    }
}

/// Which analysis backend executes per-block kernels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// AOT-compiled HLO via the PJRT CPU client (the three-layer path).
    Hlo,
    /// Pure-rust reference implementation (no artifacts needed).
    Native,
}

impl std::str::FromStr for BackendKind {
    type Err = OsebaError;

    fn from_str(s: &str) -> Result<BackendKind> {
        match s {
            "hlo" => Ok(BackendKind::Hlo),
            "native" => Ok(BackendKind::Native),
            other => Err(OsebaError::Config(format!("unknown backend '{other}'"))),
        }
    }
}

/// Full experiment/driver configuration (CLI + config file).
#[derive(Clone, Debug)]
pub struct AppConfig {
    /// Engine-level (workers / memory budget) configuration.
    pub ctx: ContextConfig,
    /// Raw dataset size in bytes (the paper's ~480 MB default, scaled).
    pub dataset_bytes: usize,
    /// Number of partitions to load into (paper: 15).
    pub num_partitions: usize,
    /// RNG seed for the generators and workloads.
    pub seed: u64,
    /// Analysis backend.
    pub backend: BackendKind,
    /// Directory holding `manifest.json` + `*.hlo.txt`.
    pub artifacts_dir: String,
    /// Simulated per-task network latency in microseconds (0 = off).
    pub net_latency_us: u64,
    /// Number of simulated cluster workers.
    pub cluster_workers: usize,
}

impl Default for AppConfig {
    fn default() -> Self {
        AppConfig {
            ctx: ContextConfig::default(),
            dataset_bytes: 480 << 20,
            num_partitions: 15,
            seed: 0x05EBA,
            backend: BackendKind::Hlo,
            artifacts_dir: "artifacts".into(),
            net_latency_us: 0,
            cluster_workers: 4,
        }
    }
}

impl AppConfig {
    /// Apply `key=value` overrides (from a config file or `--set` flags).
    pub fn apply(&mut self, map: &ConfigMap) -> Result<()> {
        for (k, v) in map.iter() {
            match k.as_str() {
                "dataset_bytes" => self.dataset_bytes = parse_bytes(v)?,
                "num_partitions" => self.num_partitions = parse_num(k, v)?,
                "seed" => self.seed = parse_num(k, v)? as u64,
                "backend" => self.backend = v.parse()?,
                "artifacts_dir" => self.artifacts_dir = v.clone(),
                "net_latency_us" => self.net_latency_us = parse_num(k, v)? as u64,
                "cluster_workers" => self.cluster_workers = parse_num(k, v)?,
                "num_workers" => self.ctx.num_workers = parse_num(k, v)?,
                "memory_budget" => self.ctx.memory_budget = Some(parse_bytes(v)?),
                other => {
                    return Err(OsebaError::Config(format!("unknown config key '{other}'")))
                }
            }
        }
        self.validate()
    }

    /// Cross-field validation.
    pub fn validate(&self) -> Result<()> {
        if self.num_partitions == 0 {
            return Err(OsebaError::Config("num_partitions must be > 0".into()));
        }
        if self.dataset_bytes == 0 {
            return Err(OsebaError::Config("dataset_bytes must be > 0".into()));
        }
        if self.cluster_workers == 0 {
            return Err(OsebaError::Config("cluster_workers must be > 0".into()));
        }
        Ok(())
    }
}

fn parse_num(key: &str, v: &str) -> Result<usize> {
    v.parse::<usize>()
        .map_err(|_| OsebaError::Config(format!("invalid number for '{key}': '{v}'")))
}

/// Parse a byte size with optional `k`/`m`/`g` suffix (binary units).
pub fn parse_bytes(v: &str) -> Result<usize> {
    let v = v.trim();
    let (num, mult) = match v.chars().last() {
        Some('k') | Some('K') => (&v[..v.len() - 1], 1usize << 10),
        Some('m') | Some('M') => (&v[..v.len() - 1], 1usize << 20),
        Some('g') | Some('G') => (&v[..v.len() - 1], 1usize << 30),
        _ => (v, 1usize),
    };
    let n: f64 = num
        .parse()
        .map_err(|_| OsebaError::Config(format!("invalid byte size '{v}'")))?;
    if n < 0.0 {
        return Err(OsebaError::Config(format!("negative byte size '{v}'")));
    }
    Ok((n * mult as f64) as usize)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_bytes_suffixes() {
        assert_eq!(parse_bytes("1024").unwrap(), 1024);
        assert_eq!(parse_bytes("4k").unwrap(), 4096);
        assert_eq!(parse_bytes("480M").unwrap(), 480 << 20);
        assert_eq!(parse_bytes("1.5g").unwrap(), 3 << 29);
        assert!(parse_bytes("abc").is_err());
        assert!(parse_bytes("-1k").is_err());
    }

    #[test]
    fn apply_overrides() {
        let mut c = AppConfig::default();
        let map = parse_config_text("num_partitions = 30\nbackend = native\nseed = 7").unwrap();
        c.apply(&map).unwrap();
        assert_eq!(c.num_partitions, 30);
        assert_eq!(c.backend, BackendKind::Native);
        assert_eq!(c.seed, 7);
    }

    #[test]
    fn unknown_key_rejected() {
        let mut c = AppConfig::default();
        let map = parse_config_text("nope = 1").unwrap();
        assert!(c.apply(&map).is_err());
    }

    #[test]
    fn validation_catches_zeroes() {
        let mut c = AppConfig::default();
        let map = parse_config_text("num_partitions = 0").unwrap();
        assert!(c.apply(&map).is_err());
    }

    #[test]
    fn backend_parse() {
        assert_eq!("hlo".parse::<BackendKind>().unwrap(), BackendKind::Hlo);
        assert_eq!("native".parse::<BackendKind>().unwrap(), BackendKind::Native);
        assert!("gpu".parse::<BackendKind>().is_err());
    }
}
