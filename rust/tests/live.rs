//! Live-ingestion consistency tests: interleaved (and concurrent) appends
//! and queries must always agree with a brute-force oracle evaluated over
//! **exactly the partitions visible at the query's pinned epoch** — no
//! torn reads, no vanishing rows across epochs.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use oseba::config::{AppConfig, ContextConfig};
use oseba::coordinator::Coordinator;
use oseba::engine::{EpochSnapshot, LiveConfig, LiveDataset};
use oseba::index::{ContentIndex, RangeQuery};
use oseba::ingest::Chunk;
use oseba::runtime::NativeBackend;
use oseba::storage::Schema;
use oseba::testing::{gen, Runner};
use oseba::util::rng::Xoshiro256;

const ROWS_PER_PART: usize = 256;

fn coord() -> Coordinator {
    let cfg = AppConfig {
        ctx: ContextConfig { num_workers: 4, memory_budget: None },
        cluster_workers: 3,
        ..Default::default()
    };
    Coordinator::new(&cfg, Arc::new(NativeBackend)).unwrap()
}

/// Block `b` of the synthetic stream: keys `[b*256, b*256+255]` (step 1),
/// price = key % 877 (exact in f32), volume = 1.
fn block_chunk(b: usize, lo: usize, hi: usize) -> Chunk {
    let keys: Vec<i64> = (lo..hi).map(|i| (b * ROWS_PER_PART + i) as i64).collect();
    let price: Vec<f32> = keys.iter().map(|&k| (k % 877) as f32).collect();
    let volume = vec![1.0; keys.len()];
    Chunk { keys, columns: vec![price, volume] }
}

/// Brute-force oracle over the snapshot's own partitions: `(count, max,
/// min)` of the price column within `q`.
fn oracle(snap: &EpochSnapshot, q: RangeQuery) -> (u64, f32, f32) {
    let mut count = 0u64;
    let mut max = f32::MIN;
    let mut min = f32::MAX;
    for p in snap.dataset().partitions() {
        for (i, &k) in p.keys.iter().enumerate() {
            if k >= q.lo && k <= q.hi {
                count += 1;
                max = max.max(p.columns[0][i]);
                min = min.min(p.columns[0][i]);
            }
        }
    }
    (count, max, min)
}

/// Check one snapshot against the oracle for `q`. Returns the row count.
fn check_snapshot(c: &Coordinator, snap: &EpochSnapshot, q: RangeQuery) -> u64 {
    let (want_count, want_max, want_min) = oracle(snap, q);
    match snap.index() {
        None => {
            assert_eq!(want_count, 0, "no index yet means nothing visible");
            0
        }
        Some(index) => {
            let got = c.analyze_period_oseba(snap.dataset(), index, q, 0);
            if want_count == 0 {
                assert!(got.is_err(), "empty selection must error, got {got:?}");
                return 0;
            }
            let got = got.unwrap_or_else(|e| {
                panic!("epoch {} query {q:?} failed: {e}", snap.epoch())
            });
            assert_eq!(got.count, want_count, "epoch {} {q:?}", snap.epoch());
            assert_eq!(got.max, want_max, "epoch {} {q:?}", snap.epoch());
            assert_eq!(got.min, want_min, "epoch {} {q:?}", snap.epoch());
            want_count
        }
    }
}

/// A randomized append schedule: `blocks` whole partitions, of which
/// `late` (none adjacent to the stream tail) are held back and appended
/// out of order afterwards; in-order blocks are split into 1–3 chunks.
#[derive(Debug)]
struct Schedule {
    blocks: usize,
    late: Vec<usize>,
    splits: Vec<usize>,
    seed: u64,
}

fn make_schedule(rng: &mut Xoshiro256) -> Schedule {
    let blocks = gen::usize_in(rng, 8, 24);
    // Hold back ~1/4 of the interior blocks (never the last block, so the
    // in-order stream always ends beyond every late block).
    let mut late = Vec::new();
    for b in 1..blocks - 1 {
        if rng.below(4) == 0 {
            late.push(b);
        }
    }
    let splits = (0..blocks).map(|_| gen::usize_in(rng, 1, 4)).collect();
    Schedule { blocks, late, splits, seed: rng.next_u64() }
}

/// Drive one schedule, calling `observe` after every append.
fn run_schedule(live: &LiveDataset, s: &Schedule, mut observe: impl FnMut()) {
    for b in 0..s.blocks {
        if s.late.contains(&b) {
            continue;
        }
        // Split the block into `splits[b]` consecutive chunks.
        let n = s.splits[b];
        let per = ROWS_PER_PART / n;
        let mut lo = 0;
        for i in 0..n {
            let hi = if i == n - 1 { ROWS_PER_PART } else { lo + per };
            live.append(block_chunk(b, lo, hi)).unwrap();
            lo = hi;
        }
        observe();
    }
    // Late blocks arrive shuffled, each as one out-of-order chunk.
    let mut order = s.late.clone();
    Xoshiro256::seeded(s.seed).shuffle(&mut order);
    for &b in &order {
        live.append(block_chunk(b, 0, ROWS_PER_PART)).unwrap();
        observe();
    }
}

#[test]
fn interleaved_appends_and_queries_match_pinned_oracle() {
    let c = coord();
    Runner::new(12, 0x11FE).run(
        "live snapshot oracle",
        make_schedule,
        |s| {
            let live = c
                .create_live(
                    Schema::stock(),
                    LiveConfig { rows_per_partition: ROWS_PER_PART, max_asl: 3 },
                )
                .unwrap();
            let mut qrng = Xoshiro256::seeded(s.seed ^ 0xABCD);
            let span = (s.blocks * ROWS_PER_PART) as i64;
            let mut last_epoch = 0;
            let mut last_rows = 0;
            run_schedule(&live, s, || {
                let snap = c.snapshot_live(&live);
                // Epochs and visible rows never go backwards.
                assert!(snap.epoch() >= last_epoch);
                assert!(snap.rows() >= last_rows);
                last_epoch = snap.epoch();
                last_rows = snap.rows();
                let (lo, hi) = gen::range_pair(&mut qrng, 0, span);
                check_snapshot(&c, &snap, RangeQuery { lo, hi });
            });
            // Final state: everything visible, whole-span query sees all.
            let snap = c.snapshot_live(&live);
            let total = (s.blocks * ROWS_PER_PART) as u64;
            assert_eq!(snap.rows() as u64, total);
            let n = check_snapshot(&c, &snap, RangeQuery { lo: 0, hi: span });
            assert_eq!(n, total);
            // Late blocks really were absorbed / rebuilt, not lost.
            let counters = live.counters();
            assert_eq!(counters.out_of_order_chunks, s.late.len());
            live.close();
            true
        },
    );
}

#[test]
fn concurrent_queries_see_only_whole_epochs() {
    let c = coord();
    let live = c
        .create_live(
            Schema::stock(),
            LiveConfig { rows_per_partition: ROWS_PER_PART, max_asl: 4 },
        )
        .unwrap();
    let mut rng = Xoshiro256::seeded(0xC0FFEE);
    let schedule = make_schedule(&mut rng);
    let span = (schedule.blocks * ROWS_PER_PART) as i64;
    let done = AtomicBool::new(false);

    std::thread::scope(|scope| {
        // Reader thread: snapshot + verify continuously while the writer
        // appends in-order and out-of-order chunks.
        let (c_ref, live_ref, done_ref) = (&c, &*live, &done);
        let reader = scope.spawn(move || {
            let mut qrng = Xoshiro256::seeded(7);
            let mut last_epoch = 0;
            let mut last_rows = 0;
            let mut checks = 0usize;
            loop {
                let finished = done_ref.load(Ordering::SeqCst);
                let snap = c_ref.snapshot_live(live_ref);
                assert!(snap.epoch() >= last_epoch, "epoch went backwards");
                assert!(snap.rows() >= last_rows, "rows vanished across epochs");
                last_epoch = snap.epoch();
                last_rows = snap.rows();
                let (lo, hi) = gen::range_pair(&mut qrng, 0, span);
                check_snapshot(c_ref, &snap, RangeQuery { lo, hi });
                checks += 1;
                if finished {
                    break;
                }
            }
            checks
        });

        run_schedule(&live, &schedule, || {});
        done.store(true, Ordering::SeqCst);
        let checks = reader.join().expect("reader thread");
        assert!(checks > 0, "reader ran at least one verification");
    });

    // After the dust settles: full-span query equals the full oracle.
    let snap = c.snapshot_live(&live);
    let total = (schedule.blocks * ROWS_PER_PART) as u64;
    assert_eq!(snap.rows() as u64, total);
    assert_eq!(check_snapshot(&c, &snap, RangeQuery { lo: 0, hi: span }), total);
    live.close();
}

/// Epoch-publication stress: one appender, one concurrent *sealer*
/// (`flush` races `append` for the write half), and several snapshot
/// readers. Every pinned snapshot must be whole — partitions sum to the
/// published row count, keys stay globally sorted, the published index
/// indexes exactly the published rows — and epochs/rows never go
/// backwards. Shaped for ThreadSanitizer: the assertions are cheap, so
/// the threads spend their time racing publication, not verifying.
#[test]
fn epoch_publication_survives_concurrent_seal_and_snapshot() {
    const BLOCKS: usize = 48;
    const READERS: usize = 4;
    let c = coord();
    let live = c
        .create_live(
            Schema::stock(),
            LiveConfig { rows_per_partition: ROWS_PER_PART, max_asl: 4 },
        )
        .unwrap();
    let span = (BLOCKS * ROWS_PER_PART) as i64;
    let done = AtomicBool::new(false);

    std::thread::scope(|scope| {
        let (c_ref, live_ref, done_ref) = (&c, &*live, &done);
        // Appender: the in-order stream, split so the unsealed tail is
        // usually non-empty when the sealer fires.
        let appender = scope.spawn(move || {
            for b in 0..BLOCKS {
                for (lo, hi) in [(0, 100), (100, ROWS_PER_PART)] {
                    live_ref.append(block_chunk(b, lo, hi)).unwrap();
                }
            }
            done_ref.store(true, Ordering::SeqCst);
        });
        // Sealer: races `flush` against the appends, forcing extra epoch
        // publications (short ASL partitions) mid-stream.
        let sealer = scope.spawn(move || {
            let mut seals = 0usize;
            while !done_ref.load(Ordering::SeqCst) {
                live_ref.flush().unwrap();
                seals += 1;
                std::thread::yield_now();
            }
            seals
        });
        let readers: Vec<_> = (0..READERS)
            .map(|r| {
                scope.spawn(move || {
                    let mut qrng = Xoshiro256::seeded(0x5EA1 + r as u64);
                    let mut last_epoch = 0u64;
                    let mut last_rows = 0usize;
                    let mut checks = 0usize;
                    loop {
                        let finished = done_ref.load(Ordering::SeqCst);
                        let snap = live_ref.snapshot();
                        assert!(snap.epoch() >= last_epoch, "reader {r}: epoch went backwards");
                        assert!(snap.rows() >= last_rows, "reader {r}: rows vanished");
                        last_epoch = snap.epoch();
                        last_rows = snap.rows();
                        let parts = snap.dataset().partitions();
                        // Whole, not torn: data sums to the published count.
                        let total: usize = parts.iter().map(|p| p.keys.len()).sum();
                        assert_eq!(
                            total,
                            snap.rows(),
                            "reader {r}: torn snapshot at epoch {}",
                            snap.epoch()
                        );
                        // In-order stream: keys stay globally sorted.
                        for w in parts.windows(2) {
                            let (prev, next) = (&w[0], &w[1]);
                            if let (Some(&a), Some(&b)) = (prev.keys.last(), next.keys.first()) {
                                assert!(a < b, "reader {r}: partitions out of key order");
                            }
                        }
                        // The published index indexes exactly the published rows.
                        if let Some(index) = snap.index() {
                            let indexed: usize = index
                                .lookup(RangeQuery { lo: 0, hi: i64::MAX })
                                .iter()
                                .map(|s| s.rows())
                                .sum();
                            assert_eq!(
                                indexed,
                                snap.rows(),
                                "reader {r}: index disagrees with epoch {}",
                                snap.epoch()
                            );
                        }
                        // Periodically run the full query oracle too.
                        if checks % 7 == 0 {
                            let (lo, hi) = gen::range_pair(&mut qrng, 0, span);
                            check_snapshot(c_ref, &snap, RangeQuery { lo, hi });
                        }
                        checks += 1;
                        if finished {
                            break;
                        }
                    }
                    checks
                })
            })
            .collect();

        appender.join().expect("appender thread");
        let seals = sealer.join().expect("sealer thread");
        assert!(seals > 0, "sealer ran at least once");
        for reader in readers {
            assert!(reader.join().expect("reader thread") > 0);
        }
    });

    // Everything visible at the end; the sealer's extra partitions hold
    // the same rows.
    let snap = c.snapshot_live(&live);
    assert_eq!(snap.rows(), BLOCKS * ROWS_PER_PART);
    assert_eq!(
        check_snapshot(&c, &snap, RangeQuery { lo: 0, hi: span }),
        (BLOCKS * ROWS_PER_PART) as u64
    );
    live.close();
}
