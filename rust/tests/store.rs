//! Persistence coverage for the tiered store: a seeded property test that
//! `save → open → analyze` reproduces the in-memory `PeriodStats`
//! bit-for-bit, plus corruption tests proving the per-section CRC check
//! rejects tampered segments with an error naming the file.

use std::sync::Arc;

use oseba::analysis::PeriodStats;
use oseba::config::{AppConfig, ContextConfig};
use oseba::coordinator::{Coordinator, IndexKind};
use oseba::datagen::ClimateGen;
use oseba::error::OsebaError;
use oseba::index::{ContentIndex, RangeQuery};
use oseba::runtime::NativeBackend;
use oseba::storage::partition_batch_uniform;
use oseba::store::{StoreManifest, TieredStore};
use oseba::testing::{gen, temp_dir, Runner};
use oseba::util::json::Json;

fn coordinator(memory_budget: Option<usize>) -> Coordinator {
    let cfg = AppConfig {
        ctx: ContextConfig { num_workers: 4, memory_budget },
        cluster_workers: 3,
        ..Default::default()
    };
    Coordinator::new(&cfg, Arc::new(NativeBackend)).unwrap()
}

fn assert_bit_equal(a: &PeriodStats, b: &PeriodStats, ctx: &str) {
    assert_eq!(a.count, b.count, "{ctx}: count");
    assert_eq!(a.max.to_bits(), b.max.to_bits(), "{ctx}: max");
    assert_eq!(a.min.to_bits(), b.min.to_bits(), "{ctx}: min");
    assert_eq!(a.mean.to_bits(), b.mean.to_bits(), "{ctx}: mean {} vs {}", a.mean, b.mean);
    assert_eq!(a.std.to_bits(), b.std.to_bits(), "{ctx}: std {} vs {}", a.std, b.std);
}

/// Save a generated dataset as a segment store under `dir`.
fn save_store(dir: &std::path::Path, rows: usize, nparts: usize, seed: u64) {
    let batch = ClimateGen { seed, ..Default::default() }.generate(rows);
    let store = TieredStore::create(
        dir,
        batch.schema.clone(),
        oseba::engine::MemoryTracker::unbounded(),
    )
    .unwrap();
    let rows_per = rows.div_ceil(nparts);
    for part in partition_batch_uniform(&batch, rows_per).unwrap() {
        store.insert(part).unwrap();
    }
    store.save().unwrap();
}

#[test]
fn prop_save_open_analyze_is_bit_identical_to_resident() {
    Runner::new(10, 0x5E6).run(
        "save → open → analyze == in-memory analyze",
        |rng| {
            let rows = gen::usize_in(rng, 500, 6_000);
            let nparts = gen::usize_in(rng, 1, 12);
            let (lo_h, hi_h) = gen::range_pair(rng, 0, rows as i64 - 1);
            // Budget between one partition and the full dataset, so some
            // cases run fully cold and some fully hot.
            let budget_parts = gen::usize_in(rng, 1, nparts + 1);
            (rows, nparts, lo_h, hi_h, budget_parts)
        },
        |&(rows, nparts, lo_h, hi_h, budget_parts)| {
            let q = RangeQuery { lo: lo_h * 3600, hi: hi_h * 3600 };
            let seed = rows as u64 ^ 0xC11A;

            // In-memory reference.
            let c = coordinator(None);
            let ds = c
                .load(ClimateGen { seed, ..Default::default() }.generate(rows), nparts)
                .unwrap();
            let index = c.build_index(&ds, IndexKind::Cias).unwrap();
            let want = c.analyze_period_oseba(&ds, index.as_ref(), q, 0).unwrap();

            // Persisted round trip under a budget sized in real partition
            // units (measured, not hand-derived from layout constants).
            let dir = temp_dir("prop-roundtrip");
            save_store(&dir, rows, nparts, seed);
            let one = ds.partitions()[0].bytes();
            let ct = coordinator(Some(budget_parts * one + one / 2));
            let (tds, tindex) = ct.open_store(&dir).unwrap();
            let got = ct.analyze_period_oseba(&tds, tindex.as_ref(), q, 0).unwrap();
            assert_bit_equal(&got, &want, "tiered vs resident");
            // The selective query faulted in only targeted partitions.
            let store = tds.store().unwrap();
            let targeted = tindex.lookup(q).len();
            assert!(
                store.counters().faults <= targeted,
                "faults {} > targeted {targeted}",
                store.counters().faults
            );
            std::fs::remove_dir_all(&dir).unwrap();
            true
        },
    );
}

#[test]
fn corrupted_segment_is_rejected_with_named_file() {
    let dir = temp_dir("corrupt");
    save_store(&dir, 4_000, 4, 7);

    // Flip one byte in the middle of one segment's column data.
    let manifest = StoreManifest::load(&dir).unwrap();
    let victim = dir.join(&manifest.segments[2].file);
    let mut bytes = std::fs::read(&victim).unwrap();
    let off = bytes.len() * 3 / 5;
    bytes[off] ^= 0x01;
    std::fs::write(&victim, &bytes).unwrap();

    let c = coordinator(None);
    let (ds, index) = c.open_store(&dir).unwrap();
    // Partition 2 holds rows 2000..3000 → keys 2000h..2999h.
    let bad_q = RangeQuery { lo: 2_100 * 3600, hi: 2_200 * 3600 };
    let err = c.analyze_period_oseba(&ds, index.as_ref(), bad_q, 0).unwrap_err();
    let msg = err.to_string();
    assert!(matches!(err, OsebaError::Store(_)), "got: {err:?}");
    assert!(
        msg.contains(&manifest.segments[2].file),
        "error must name the segment file, got: {msg}"
    );
    assert!(msg.contains("crc") || msg.contains("mismatch"), "got: {msg}");

    // Untouched partitions still serve queries.
    let good_q = RangeQuery { lo: 0, hi: 500 * 3600 };
    let st = c.analyze_period_oseba(&ds, index.as_ref(), good_q, 0).unwrap();
    assert_eq!(st.count, 501);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn tampered_manifest_is_rejected() {
    let dir = temp_dir("bad-manifest");
    save_store(&dir, 2_000, 2, 3);
    let path = dir.join(oseba::store::MANIFEST_FILE);
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::write(&path, text.replace("oseba-store", "bogus")).unwrap();
    let c = coordinator(None);
    let err = c.open_store(&dir).unwrap_err();
    assert!(err.to_string().contains("manifest"), "got: {err}");

    std::fs::write(&path, "{ not json").unwrap();
    assert!(c.open_store(&dir).is_err());

    std::fs::remove_file(&path).unwrap();
    let err = c.open_store(&dir).unwrap_err();
    assert!(err.to_string().contains("manifest.json"), "got: {err}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn manifest_sketch_width_mismatch_is_a_clear_store_error() {
    // A v3 manifest whose per-segment sketch list disagrees with the
    // schema's value-column count must fail `open` with an explicit
    // `OsebaError::Store` naming the mismatch — never a silent
    // column-index confusion when a covered query later reads the wrong
    // column's sums.
    let dir = temp_dir("bad-sketch");
    save_store(&dir, 2_000, 2, 5);
    let path = dir.join(oseba::store::MANIFEST_FILE);
    let mut doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    {
        let Json::Obj(top) = &mut doc else { panic!("manifest is an object") };
        let Some(Json::Arr(segs)) = top.get_mut("segments") else { panic!("segments") };
        let Json::Obj(seg) = &mut segs[0] else { panic!("segment object") };
        let Some(Json::Arr(sks)) = seg.get_mut("sketch") else { panic!("sketch array") };
        sks.push(sks[0].clone()); // 5 sketch columns for the 4-column schema
    }
    std::fs::write(&path, doc.to_string()).unwrap();

    let c = coordinator(None);
    let err = c.open_store(&dir).unwrap_err();
    assert!(matches!(err, OsebaError::Store(_)), "got: {err:?}");
    assert!(err.to_string().contains("sketch columns"), "got: {err}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn tampered_filter_section_is_rejected() {
    // Every way a v4 manifest's per-segment `filter` array can go bad must
    // fail `open` with an explicit `OsebaError::Store` — a silently
    // accepted corrupt filter could prune a partition that holds matches.
    let dir = temp_dir("bad-filter");
    save_store(&dir, 2_000, 2, 9);
    let path = dir.join(oseba::store::MANIFEST_FILE);
    let pristine = std::fs::read_to_string(&path).unwrap();
    let c = coordinator(None);

    let mutate = |f: &dyn Fn(&mut Vec<Json>)| -> OsebaError {
        let mut doc = Json::parse(&pristine).unwrap();
        {
            let Json::Obj(top) = &mut doc else { panic!("manifest is an object") };
            let Some(Json::Arr(segs)) = top.get_mut("segments") else { panic!("segments") };
            let Json::Obj(seg) = &mut segs[0] else { panic!("segment object") };
            let Some(Json::Arr(fs)) = seg.get_mut("filter") else { panic!("filter array") };
            f(fs);
        }
        std::fs::write(&path, doc.to_string()).unwrap();
        c.open_store(&dir).unwrap_err()
    };

    // A flipped hex character anywhere in the section fails its CRC.
    let err = mutate(&|fs| {
        let Json::Str(h) = &mut fs[0] else { panic!("hex string") };
        let flip = if h.as_bytes()[0] == b'0' { "1" } else { "0" };
        h.replace_range(0..1, flip);
    });
    assert!(matches!(err, OsebaError::Store(_)), "got: {err:?}");
    assert!(err.to_string().contains("crc mismatch"), "got: {err}");

    // Too short to even hold the CRC prefix.
    let err = mutate(&|fs| fs[0] = Json::str("ab"));
    assert!(matches!(err, OsebaError::Store(_)), "got: {err:?}");
    assert!(err.to_string().contains("truncated"), "got: {err}");

    // Odd-length and non-hex sections are named, not panicked on.
    let err = mutate(&|fs| fs[0] = Json::str("abc"));
    assert!(err.to_string().contains("odd hex length"), "got: {err}");
    let err = mutate(&|fs| fs[0] = Json::str("zz"));
    assert!(err.to_string().contains("non-hex"), "got: {err}");

    // A filter list disagreeing with the schema's column count would
    // probe the wrong column's membership — rejected outright.
    let err = mutate(&|fs| fs.push(fs[0].clone()));
    assert!(matches!(err, OsebaError::Store(_)), "got: {err:?}");
    assert!(err.to_string().contains("filter columns"), "got: {err}");

    // Wrong JSON type inside the array.
    let err = mutate(&|fs| fs[0] = Json::num(1.0));
    assert!(err.to_string().contains("hex string"), "got: {err}");

    // The pristine manifest still opens (the harness itself is sound),
    // and an explicit `"filter": null` opt-out opens filterless.
    std::fs::write(&path, &pristine).unwrap();
    let (ds, _) = c.open_store(&dir).unwrap();
    assert!(ds.filter_bytes() > 0, "v4 store restores filters");
    c.context().unpersist(&ds);
    let mut doc = Json::parse(&pristine).unwrap();
    {
        let Json::Obj(top) = &mut doc else { panic!("manifest is an object") };
        let Some(Json::Arr(segs)) = top.get_mut("segments") else { panic!("segments") };
        for seg in segs.iter_mut() {
            let Json::Obj(seg) = seg else { panic!("segment object") };
            seg.insert("filter".into(), Json::Null);
        }
    }
    std::fs::write(&path, doc.to_string()).unwrap();
    let (ds, index) = c.open_store(&dir).unwrap();
    assert_eq!(ds.filter_bytes(), 0, "null filters mean none restored");
    // Filterless stores still answer queries (filters only ever prune).
    let st = c
        .analyze_period_oseba(&ds, index.as_ref(), RangeQuery { lo: 0, hi: i64::MAX }, 0)
        .unwrap();
    assert_eq!(st.count, 2_000);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn tampered_blocks_section_is_rejected() {
    // Every way a v5 manifest's per-segment `blocks` section can go bad
    // must fail `open` with an explicit `OsebaError::Store` — a silently
    // accepted corrupt hierarchy could prune a block that holds matches
    // or answer one from garbage partials.
    let dir = temp_dir("bad-blocks");
    save_store(&dir, 2_000, 2, 11);
    let path = dir.join(oseba::store::MANIFEST_FILE);
    let pristine = std::fs::read_to_string(&path).unwrap();
    let c = coordinator(None);

    let mutate = |f: &dyn Fn(&mut Json)| -> OsebaError {
        let mut doc = Json::parse(&pristine).unwrap();
        {
            let Json::Obj(top) = &mut doc else { panic!("manifest is an object") };
            let Some(Json::Arr(segs)) = top.get_mut("segments") else { panic!("segments") };
            let Json::Obj(seg) = &mut segs[0] else { panic!("segment object") };
            let Some(b) = seg.get_mut("blocks") else { panic!("blocks section") };
            f(b);
        }
        std::fs::write(&path, doc.to_string()).unwrap();
        c.open_store(&dir).unwrap_err()
    };

    // A flipped hex character in the payload (past the 8-char CRC prefix)
    // fails the section CRC.
    let err = mutate(&|b| {
        let Json::Str(h) = b else { panic!("hex string") };
        let flip = if h.as_bytes()[10] == b'0' { "1" } else { "0" };
        h.replace_range(10..11, flip);
    });
    assert!(matches!(err, OsebaError::Store(_)), "got: {err:?}");
    assert!(err.to_string().contains("crc mismatch"), "got: {err}");

    // Too short to even hold the CRC prefix.
    let err = mutate(&|b| *b = Json::str("ab"));
    assert!(matches!(err, OsebaError::Store(_)), "got: {err:?}");
    assert!(err.to_string().contains("truncated"), "got: {err}");

    // Odd-length and non-hex sections are named, not panicked on.
    let err = mutate(&|b| *b = Json::str("abc"));
    assert!(err.to_string().contains("odd hex length"), "got: {err}");
    let err = mutate(&|b| *b = Json::str("zz"));
    assert!(err.to_string().contains("non-hex"), "got: {err}");

    // Wrong JSON type.
    let err = mutate(&|b| *b = Json::num(1.0));
    assert!(err.to_string().contains("hex string"), "got: {err}");

    // The pristine manifest still opens (the harness itself is sound);
    // an explicit `"blocks": null` opt-out opens block-blind and still
    // answers — block sketches only ever accelerate.
    std::fs::write(&path, &pristine).unwrap();
    let (ds, _) = c.open_store(&dir).unwrap();
    c.context().unpersist(&ds);
    let mut doc = Json::parse(&pristine).unwrap();
    {
        let Json::Obj(top) = &mut doc else { panic!("manifest is an object") };
        let Some(Json::Arr(segs)) = top.get_mut("segments") else { panic!("segments") };
        for seg in segs.iter_mut() {
            let Json::Obj(seg) = seg else { panic!("segment object") };
            seg.insert("blocks".into(), Json::Null);
        }
    }
    std::fs::write(&path, doc.to_string()).unwrap();
    let (ds, index) = c.open_store(&dir).unwrap();
    let st = c
        .analyze_period_oseba(&ds, index.as_ref(), RangeQuery { lo: 0, hi: i64::MAX }, 0)
        .unwrap();
    assert_eq!(st.count, 2_000);
    c.context().unpersist(&ds);

    // A v4 manifest (no `blocks` field at all) still opens: pre-v5
    // segments get the "no block sketches → scan" sentinel.
    let mut doc = Json::parse(&pristine).unwrap();
    {
        let Json::Obj(top) = &mut doc else { panic!("manifest is an object") };
        top.insert("version".into(), Json::num(4.0));
        let Some(Json::Arr(segs)) = top.get_mut("segments") else { panic!("segments") };
        for seg in segs.iter_mut() {
            let Json::Obj(seg) = seg else { panic!("segment object") };
            seg.remove("blocks");
        }
    }
    std::fs::write(&path, doc.to_string()).unwrap();
    let (ds, index) = c.open_store(&dir).unwrap();
    let st = c
        .analyze_period_oseba(&ds, index.as_ref(), RangeQuery { lo: 0, hi: i64::MAX }, 0)
        .unwrap();
    assert_eq!(st.count, 2_000);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn opened_store_answers_covered_queries_from_manifest_sketches() {
    use oseba::coordinator::{plan_query, Query};
    let dir = temp_dir("open-sketch");
    let rows = 8_000;
    save_store(&dir, rows, 8, 0xA11);

    // Tiny budget: everything stays Cold after open. A fully-covered
    // query must still answer — from the manifest-restored sketches —
    // with zero faults and zero segment bytes read.
    let c = coordinator(Some(1));
    let (ds, index) = c.open_store(&dir).unwrap();
    let q = RangeQuery { lo: 0, hi: i64::MAX };
    let query = Query::stats(q, 0);
    let plan = plan_query(&ds, index.as_ref(), &query, true).unwrap();
    assert_eq!(plan.explain.agg_answered, 8);
    let store = ds.store().unwrap();
    let before = store.counters();
    let got = match c.execute_physical(&ds, &plan, &query).unwrap() {
        oseba::coordinator::QueryOutput::Stats(s) => s,
        other => panic!("stats output, got {other:?}"),
    };
    let d = store.counters().since(&before);
    assert_eq!((d.faults, d.segment_bytes_read), (0, 0), "no data touched");
    assert_eq!(got.count, rows as u64);

    // And the answer is bit-identical to the fully-resident reference.
    let cr = coordinator(None);
    let rds = cr
        .load(
            ClimateGen { seed: 0xA11, ..Default::default() }.generate(rows),
            8,
        )
        .unwrap();
    let rindex = cr.build_index(&rds, IndexKind::Cias).unwrap();
    let want = cr.analyze_period_oseba(&rds, rindex.as_ref(), q, 0).unwrap();
    assert_bit_equal(&got, &want, "manifest sketches vs resident");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn batch_over_opened_store_matches_resident_batch() {
    let dir = temp_dir("batch-roundtrip");
    let rows = 30_000;
    save_store(&dir, rows, 15, 0x05EBA);

    let c = coordinator(None);
    let ds = c
        .load(
            ClimateGen { seed: 0x05EBA, ..Default::default() }.generate(rows),
            15,
        )
        .unwrap();
    let index = c.build_index(&ds, IndexKind::Cias).unwrap();
    let h = 3600i64;
    let qs = vec![
        RangeQuery { lo: 0, hi: 4_000 * h },
        RangeQuery { lo: 2_000 * h, hi: 9_000 * h },
        RangeQuery { lo: 20_000 * h, hi: 22_000 * h },
    ];
    let want = c.analyze_batch(&ds, index.as_ref(), &qs, 1).unwrap();

    // Budget ~2 partitions: the batch must fault selectively, not reload.
    let one = ds.partitions()[0].bytes();
    let ct = coordinator(Some(2 * one + one / 2));
    let (tds, tindex) = ct.open_store(&dir).unwrap();
    let (got, report) =
        ct.analyze_batch_with_report(&tds, tindex.as_ref(), &qs, 1).unwrap();
    for (g, e) in got.iter().zip(&want) {
        assert_bit_equal(g, e, "batch");
    }
    assert!(report.faults > 0);
    let store = tds.store().unwrap();
    assert!(
        store.counters().segment_bytes_read < store.total_bytes(),
        "selective batch must not read the whole dataset ({} of {})",
        store.counters().segment_bytes_read,
        store.total_bytes()
    );
    std::fs::remove_dir_all(&dir).unwrap();
}
