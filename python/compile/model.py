"""Layer-2 JAX analysis graphs.

Each public function here is an AOT entry point: ``aot.py`` lowers it once
per static configuration to HLO text that the rust runtime loads and
executes on its request path. The functions wrap the L1 pallas kernels and
add whatever graph-level composition the analysis needs (e.g. the fused
stats-of-moving-average pipeline used by the L2-fusion ablation).

Shapes are the AOT contract (DESIGN.md §3): blocks are f32[BLOCK_ROWS],
range scalars are i32, and every entry returns a flat tuple of arrays so the
rust side can unpack with ``to_tuple``.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import (BLOCK_ROWS, HIST_BINS, MA_WINDOWS, distance,
                      histogram64, moving_average, segment_stats)
from .kernels.segment_stats import segment_stats_grid, STATS_BATCH, STATS_BATCHES

__all__ = [
    "BLOCK_ROWS", "HIST_BINS", "MA_WINDOWS", "STATS_BATCH",
    "block_stats", "block_stats_grid", "block_moving_average",
    "block_distance", "block_histogram", "block_ma_stats",
]


def block_stats(x, start, end):
    """Masked moments of one block — the Fig 4/6 per-partition task."""
    return segment_stats(x, start, end)


def block_stats_grid(xs, starts, ends):
    """Moments of STATS_BATCH blocks in one dispatch (perf variant)."""
    return segment_stats_grid(xs, starts, ends)


def block_moving_average(x, start, end, *, window):
    """Trailing ``window``-point MA over the selected rows of one block."""
    return (moving_average(x, start, end, window=window),)


def block_distance(a, b, start, end):
    """Distance partials between two aligned blocks."""
    return distance(a, b, start, end)


def block_histogram(x, start, end, lo, hi):
    """64-bin histogram of the selected rows of one block."""
    return (histogram64(x, start, end, lo, hi),)


def block_ma_stats(x, start, end, *, window):
    """Fused pipeline: moments of the MA series (trend statistics).

    Used by the L2-fusion ablation: computing MA and stats as one lowered
    graph keeps the intermediate series in the executable (no extra
    host↔device round trip or host-side buffer), exactly the paper's
    "don't materialize the intermediate" argument applied at L2.
    """
    ma = moving_average(x, start, end, window=window)
    # Valid MA points live in [start+window-1, end).
    s = jnp.asarray(start, jnp.int32) + (window - 1)
    return segment_stats(ma, s, end)


# --- AOT entry registry -----------------------------------------------------

_F32B = jax.ShapeDtypeStruct((BLOCK_ROWS,), jnp.float32)
_I32 = jax.ShapeDtypeStruct((), jnp.int32)
_F32 = jax.ShapeDtypeStruct((), jnp.float32)


def entries():
    """name → (fn, example_args) for every artifact aot.py must emit.

    The manifest the rust runtime reads is generated from this registry, so
    adding an entry here is the single step to expose a new analysis.
    """
    reg = {
        "segment_stats": (block_stats, (_F32B, _I32, _I32)),
        "distance": (block_distance, (_F32B, _F32B, _I32, _I32)),
        "histogram64": (block_histogram, (_F32B, _I32, _I32, _F32, _F32)),
    }
    for b in STATS_BATCHES:
        reg[f"segment_stats_b{b}"] = (
            block_stats_grid,
            (
                jax.ShapeDtypeStruct((b, BLOCK_ROWS), jnp.float32),
                jax.ShapeDtypeStruct((b,), jnp.int32),
                jax.ShapeDtypeStruct((b,), jnp.int32),
            ),
        )
    for w in MA_WINDOWS:
        reg[f"moving_average_w{w}"] = (
            functools.partial(block_moving_average, window=w),
            (_F32B, _I32, _I32),
        )
        reg[f"ma_stats_w{w}"] = (
            functools.partial(block_ma_stats, window=w),
            (_F32B, _I32, _I32),
        )
    return reg
