//! Fixed-size thread pool with a shared injector queue (no `tokio`/`rayon`
//! in the vendored set).
//!
//! Used by the simulated cluster's workers and the interactive server. Jobs
//! are boxed closures; `scope_execute` provides the common "run N tasks,
//! wait for all" pattern with panic propagation, which is what the
//! coordinator's stage execution needs.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    available: Condvar,
    shutdown: AtomicBool,
    in_flight: AtomicUsize,
    idle: Condvar,
    idle_lock: Mutex<()>,
}

/// A fixed-size pool of worker threads.
pub struct ThreadPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Spawn `size` worker threads (`size >= 1` enforced).
    pub fn new(size: usize) -> ThreadPool {
        let size = size.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
            in_flight: AtomicUsize::new(0),
            idle: Condvar::new(),
            idle_lock: Mutex::new(()),
        });
        let handles = (0..size)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("oseba-worker-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker thread")
            })
            .collect();
        ThreadPool { shared, handles, size }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Enqueue a job; it runs on some worker thread.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, job: F) {
        self.shared.in_flight.fetch_add(1, Ordering::SeqCst);
        self.shared.queue.lock().unwrap().push_back(Box::new(job));
        self.shared.available.notify_one();
    }

    /// Block until every queued job has completed.
    pub fn wait_idle(&self) {
        let mut guard = self.shared.idle_lock.lock().unwrap();
        while self.shared.in_flight.load(Ordering::SeqCst) != 0 {
            guard = self.shared.idle.wait(guard).unwrap();
        }
    }

    /// Run all `tasks` on the pool and collect results in input order.
    /// Panics in tasks are propagated (first panic wins).
    pub fn scope_execute<T, F>(&self, tasks: Vec<F>) -> Vec<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let n = tasks.len();
        let results: Arc<Mutex<Vec<Option<std::thread::Result<T>>>>> =
            Arc::new(Mutex::new((0..n).map(|_| None).collect()));
        for (i, task) in tasks.into_iter().enumerate() {
            let results = Arc::clone(&results);
            self.execute(move || {
                let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(task));
                results.lock().unwrap()[i] = Some(r);
            });
        }
        self.wait_idle();
        let slots = Arc::try_unwrap(results)
            .unwrap_or_else(|_| panic!("scope_execute: dangling result refs"))
            .into_inner()
            .unwrap();
        slots
            .into_iter()
            .map(|slot| match slot.expect("task completed") {
                Ok(v) => v,
                Err(p) => std::panic::resume_unwind(p),
            })
            .collect()
    }
}

fn worker_loop(sh: Arc<Shared>) {
    loop {
        let job = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if let Some(job) = q.pop_front() {
                    break job;
                }
                if sh.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                q = sh.available.wait(q).unwrap();
            }
        };
        job();
        if sh.in_flight.fetch_sub(1, Ordering::SeqCst) == 1 {
            let _guard = sh.idle_lock.lock().unwrap();
            sh.idle.notify_all();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.available.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn scope_execute_preserves_order() {
        let pool = ThreadPool::new(3);
        let tasks: Vec<_> = (0..50).map(|i| move || i * 2).collect();
        let out = pool.scope_execute(tasks);
        assert_eq!(out, (0..50).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn scope_execute_actually_parallel() {
        // With 4 threads and 4 sleeping tasks, wall time ≈ one task.
        let pool = ThreadPool::new(4);
        let t0 = std::time::Instant::now();
        let tasks: Vec<_> = (0..4)
            .map(|_| move || std::thread::sleep(std::time::Duration::from_millis(50)))
            .collect();
        pool.scope_execute(tasks);
        assert!(t0.elapsed() < std::time::Duration::from_millis(160));
    }

    #[test]
    #[should_panic(expected = "task boom")]
    fn scope_execute_propagates_panic() {
        let pool = ThreadPool::new(2);
        let tasks: Vec<Box<dyn FnOnce() -> i32 + Send>> = vec![
            Box::new(|| 1),
            Box::new(|| panic!("task boom")),
        ];
        pool.scope_execute(tasks);
    }

    #[test]
    fn wait_idle_on_empty_pool_returns() {
        let pool = ThreadPool::new(2);
        pool.wait_idle();
    }

    #[test]
    fn size_clamped_to_one() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.size(), 1);
        let out = pool.scope_execute(vec![|| 7]);
        assert_eq!(out, vec![7]);
    }
}
