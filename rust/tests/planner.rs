//! Property tests for the batch query planner and the concurrent
//! multi-query execution path:
//!
//! 1. Coverage: the merged plan covers *exactly* the union of the input
//!    ranges (oracle: a brute-force coverage bitmap over a bounded key
//!    domain).
//! 2. Plan invariants: sorted, disjoint, non-adjacent ranges; the sources
//!    lists partition the input indices.
//! 3. Execution: batch stats equal per-query single-path stats for random
//!    overlapping workloads.
//! 4. Cost: N overlapping queries touch each intersecting partition
//!    exactly once per batch (engine counters), never once per query.

use oseba::config::{AppConfig, ContextConfig};
use oseba::coordinator::{plan_batch, Coordinator, IndexKind};
use oseba::datagen::ClimateGen;
use oseba::index::{ContentIndex, RangeQuery};
use oseba::runtime::NativeBackend;
use oseba::testing::{gen, Runner};
use oseba::util::rng::Xoshiro256;
use std::sync::Arc;

const DOMAIN: i64 = 2_000;

fn random_query_set(rng: &mut Xoshiro256) -> Vec<RangeQuery> {
    let n = gen::usize_in(rng, 0, 12);
    (0..n)
        .map(|_| {
            let (lo, hi) = gen::range_pair(rng, 0, DOMAIN - 1);
            RangeQuery { lo, hi }
        })
        .collect()
}

/// Brute-force coverage oracle over the bounded domain.
fn coverage(queries: &[RangeQuery]) -> Vec<bool> {
    let mut cov = vec![false; DOMAIN as usize];
    for q in queries {
        for k in q.lo..=q.hi.min(DOMAIN - 1) {
            cov[k as usize] = true;
        }
    }
    cov
}

#[test]
fn prop_plan_covers_exactly_the_union() {
    Runner::default().run(
        "plan coverage == union of inputs",
        random_query_set,
        |queries| {
            let plan = plan_batch(queries);
            let want = coverage(queries);
            let got = coverage(&plan.iter().map(|p| p.range).collect::<Vec<_>>());
            want == got
        },
    );
}

#[test]
fn prop_plan_invariants_hold() {
    Runner::default().run(
        "plan sorted/disjoint/non-adjacent; sources partition inputs",
        random_query_set,
        |queries| {
            let plan = plan_batch(queries);
            let disjoint = plan
                .windows(2)
                .all(|w| (w[0].range.hi as i128) + 1 < w[1].range.lo as i128);
            let mut seen: Vec<usize> = plan.iter().flat_map(|p| p.sources.clone()).collect();
            seen.sort_unstable();
            let complete = seen == (0..queries.len()).collect::<Vec<_>>();
            // Every source lies inside its merged range.
            let contained = plan.iter().all(|p| {
                p.sources
                    .iter()
                    .all(|&i| p.range.lo <= queries[i].lo && queries[i].hi <= p.range.hi)
            });
            disjoint && complete && contained
        },
    );
}

#[test]
fn prop_segments_partition_each_merged_range() {
    Runner::default().run(
        "elementary segments tile each merged range",
        random_query_set,
        |queries| {
            plan_batch(queries).iter().all(|pq| {
                let segs = pq.segments(queries);
                if segs.is_empty() {
                    return false;
                }
                let tiles = segs.first().unwrap().0.lo == pq.range.lo
                    && segs.last().unwrap().0.hi == pq.range.hi
                    && segs.windows(2).all(|w| w[0].0.hi + 1 == w[1].0.lo);
                // Each covering set is non-empty and sources-only.
                let covers = segs
                    .iter()
                    .all(|(_, c)| !c.is_empty() && c.iter().all(|i| pq.sources.contains(i)));
                tiles && covers
            })
        },
    );
}

fn coordinator() -> Coordinator {
    let cfg = AppConfig {
        ctx: ContextConfig { num_workers: 4, memory_budget: None },
        cluster_workers: 3,
        ..Default::default()
    };
    Coordinator::new(&cfg, Arc::new(NativeBackend)).unwrap()
}

#[test]
fn prop_batch_stats_equal_single_query_stats() {
    let coord = coordinator();
    let rows = 20_000usize;
    let ds = coord.load(ClimateGen::default().generate(rows), 10).unwrap();
    let index = coord.build_index(&ds, IndexKind::Cias).unwrap();
    Runner::new(16, 0xBA7C4).run(
        "batch demux == per-query single path",
        |rng| {
            let n = gen::usize_in(rng, 1, 8);
            (0..n)
                .map(|_| {
                    let (lo_h, hi_h) = gen::range_pair(rng, 0, rows as i64 - 1);
                    RangeQuery { lo: lo_h * 3600, hi: hi_h * 3600 }
                })
                .collect::<Vec<_>>()
        },
        |queries| {
            let batch = coord.analyze_batch(&ds, index.as_ref(), queries, 0).unwrap();
            queries.iter().zip(&batch).all(|(q, got)| {
                let want = coord.analyze_period_oseba(&ds, index.as_ref(), *q, 0).unwrap();
                got.count == want.count
                    && got.max == want.max
                    && got.min == want.min
                    && (got.mean - want.mean).abs() < 1e-6
                    && (got.std - want.std).abs() < 1e-6
            })
        },
    );
}

#[test]
fn overlapping_queries_touch_each_partition_once_per_batch() {
    let coord = coordinator();
    let ds = coord.load(ClimateGen::default().generate(30_000), 15).unwrap();
    let index = coord.build_index(&ds, IndexKind::Cias).unwrap();
    let h = 3600i64;

    // Eight heavily-overlapping queries over hours [0, 9500]: every one of
    // them intersects the leading partitions.
    let queries: Vec<RangeQuery> = (0..8)
        .map(|i| RangeQuery { lo: i as i64 * 500 * h, hi: (6_000 + i as i64 * 500) * h })
        .collect();
    let union = RangeQuery { lo: 0, hi: 9_500 * h };
    let union_parts = index.lookup(union).len();
    assert!(union_parts >= 5, "the union spans several partitions");

    let before = coord.context().counters();
    let (stats, report) = coord
        .analyze_batch_with_report(&ds, index.as_ref(), &queries, 0)
        .unwrap();
    let after = coord.context().counters();

    // The acceptance check: each intersecting partition is targeted once
    // for the whole batch, not once per query.
    assert_eq!(
        after.partitions_targeted - before.partitions_targeted,
        union_parts,
        "one touch per partition per batch"
    );
    let naive: usize = queries.iter().map(|q| index.lookup(*q).len()).sum();
    assert!(
        naive > 3 * union_parts,
        "naive execution would touch far more ({naive} vs {union_parts})"
    );
    assert_eq!(after.partitions_scanned, before.partitions_scanned, "no scans");
    assert_eq!(report.merged_ranges, 1);
    assert_eq!(stats.len(), queries.len());

    // And the merged execution still answers every query correctly.
    for (i, q) in queries.iter().enumerate() {
        let want = coord.analyze_period_oseba(&ds, index.as_ref(), *q, 0).unwrap();
        assert_eq!(stats[i].count, want.count, "query {i}");
        assert_eq!(stats[i].max, want.max, "query {i}");
    }
}
