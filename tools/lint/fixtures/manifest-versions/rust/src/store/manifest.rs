//! Seeded violation: the reader guards the v2 (zones), v3 (sketches) and
//! v4 (filters) upgrades but not v5 (block sketches), while VERSION says
//! the writer can emit v5.

pub const VERSION: u32 = 5;
pub const MIN_VERSION: u32 = 1;

pub fn to_json(version: u32) -> u32 {
    VERSION + version
}

pub fn from_json(version: u32) -> bool {
    if version < MIN_VERSION || version > VERSION {
        return false;
    }
    if version < 2 {
        // v1 upgrade path handled...
        return true;
    }
    if version < 3 {
        // ...v2 upgrade path handled...
        return true;
    }
    if version < 4 {
        // ...v3 upgrade path handled...
        return true;
    }
    // ...but no `version < 5` guard — the seeded violation.
    true
}
