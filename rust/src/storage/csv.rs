//! CSV import/export for [`RecordBatch`] — the real-small-dataset path
//! (load an actual climate/stock CSV instead of the generators).
//!
//! Format: a header row naming the key column first, then one row per
//! record; the key parses as i64, values as f32. Rows must arrive sorted
//! by key (the engine's invariant); a violation is a load error, not a
//! silent re-sort.

use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::error::{OsebaError, Result};
use crate::storage::batch::{BatchBuilder, RecordBatch};
use crate::storage::schema::Schema;

/// Parse a batch from CSV text (header + rows).
pub fn read_csv<R: Read>(reader: R) -> Result<RecordBatch> {
    let mut lines = BufReader::new(reader).lines();
    let header = lines
        .next()
        .ok_or_else(|| OsebaError::Schema("empty csv".into()))??;
    let mut cols = header.split(',').map(str::trim);
    let key = cols
        .next()
        .filter(|k| !k.is_empty())
        .ok_or_else(|| OsebaError::Schema("missing key column in header".into()))?;
    let value_cols: Vec<&str> = cols.collect();
    let schema = Schema::new(key, &value_cols)?;
    let width = schema.width();
    let mut b = BatchBuilder::new(schema);

    let mut row = vec![0f32; width];
    for (lineno, line) in lines.enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let mut fields = line.split(',').map(str::trim);
        let key: i64 = fields
            .next()
            .unwrap_or("")
            .parse()
            .map_err(|_| bad_row(lineno, "key not an integer"))?;
        for (c, slot) in row.iter_mut().enumerate() {
            let f = fields
                .next()
                .ok_or_else(|| bad_row(lineno, &format!("missing column {}", c + 1)))?;
            *slot = f.parse().map_err(|_| bad_row(lineno, "value not a number"))?;
        }
        if fields.next().is_some() {
            return Err(bad_row(lineno, "too many columns"));
        }
        if let Some(&last) = b_last_key(&b) {
            if key < last {
                return Err(bad_row(lineno, "keys not sorted"));
            }
        }
        b.push(key, &row);
    }
    b.finish()
}

fn b_last_key(b: &BatchBuilder) -> Option<&i64> {
    // BatchBuilder doesn't expose keys; track via rows — use a tiny helper
    // on the builder instead.
    b.last_key()
}

fn bad_row(lineno: usize, msg: &str) -> OsebaError {
    // +2: one for the header, one for 1-based numbering.
    OsebaError::Schema(format!("csv row {}: {msg}", lineno + 2))
}

/// Load a batch from a CSV file. I/O failures name the file.
pub fn load_csv(path: impl AsRef<Path>) -> Result<RecordBatch> {
    let path = path.as_ref();
    let file = std::fs::File::open(path).map_err(|e| OsebaError::io(path, e))?;
    read_csv(file).map_err(|e| match e {
        // Re-attach the path to read errors surfaced as bare io.
        OsebaError::Io { path: p, source } if p.as_os_str().is_empty() => {
            OsebaError::io(path, source)
        }
        other => other,
    })
}

/// Write a batch as CSV (header + rows).
pub fn write_csv<W: Write>(batch: &RecordBatch, writer: W) -> Result<()> {
    let mut w = BufWriter::new(writer);
    write!(w, "{}", batch.schema.key)?;
    for c in &batch.schema.columns {
        write!(w, ",{c}")?;
    }
    writeln!(w)?;
    for r in 0..batch.rows() {
        write!(w, "{}", batch.keys[r])?;
        for c in &batch.columns {
            write!(w, ",{}", c[r])?;
        }
        writeln!(w)?;
    }
    w.flush()?;
    Ok(())
}

/// Save a batch to a CSV file. I/O failures name the file.
pub fn save_csv(batch: &RecordBatch, path: impl AsRef<Path>) -> Result<()> {
    let path = path.as_ref();
    let file = std::fs::File::create(path).map_err(|e| OsebaError::io(path, e))?;
    write_csv(batch, file).map_err(|e| match e {
        OsebaError::Io { path: p, source } if p.as_os_str().is_empty() => {
            OsebaError::io(path, source)
        }
        other => other,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
time,temperature,humidity
0,20.5,80
3600,21.0,78.5
7200,19.75,82
";

    #[test]
    fn parses_sample() {
        let b = read_csv(SAMPLE.as_bytes()).unwrap();
        assert_eq!(b.schema.key, "time");
        assert_eq!(b.schema.columns, vec!["temperature", "humidity"]);
        assert_eq!(b.keys, vec![0, 3600, 7200]);
        assert_eq!(b.column("temperature").unwrap(), &[20.5, 21.0, 19.75]);
    }

    #[test]
    fn roundtrips() {
        let b = read_csv(SAMPLE.as_bytes()).unwrap();
        let mut out = Vec::new();
        write_csv(&b, &mut out).unwrap();
        let b2 = read_csv(out.as_slice()).unwrap();
        assert_eq!(b.keys, b2.keys);
        assert_eq!(b.columns, b2.columns);
        assert_eq!(b.schema, b2.schema);
    }

    #[test]
    fn roundtrips_generated_data_through_files() {
        let gen = crate::datagen::ClimateGen::default().generate(500);
        let dir = crate::testing::temp_dir("csv");
        let path = dir.join("climate.csv");
        save_csv(&gen, &path).unwrap();
        let back = load_csv(&path).unwrap();
        assert_eq!(back.rows(), 500);
        assert_eq!(back.keys, gen.keys);
        for (a, b) in back.columns.iter().zip(&gen.columns) {
            for (x, y) in a.iter().zip(b) {
                assert!((x - y).abs() < 1e-4);
            }
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rejects_malformed_rows() {
        assert!(read_csv("".as_bytes()).is_err());
        let unsorted = "time,a\n10,1\n5,2\n";
        assert!(read_csv(unsorted.as_bytes()).is_err());
        let short = "time,a,b\n1,2\n";
        assert!(read_csv(short.as_bytes()).is_err());
        let long = "time,a\n1,2,3\n";
        assert!(read_csv(long.as_bytes()).is_err());
        let badkey = "time,a\nx,2\n";
        assert!(read_csv(badkey.as_bytes()).is_err());
        let badval = "time,a\n1,x\n";
        assert!(read_csv(badval.as_bytes()).is_err());
    }

    #[test]
    fn blank_lines_skipped() {
        let b = read_csv("time,a\n1,2\n\n2,3\n".as_bytes()).unwrap();
        assert_eq!(b.rows(), 2);
    }

    #[test]
    fn file_errors_name_the_path() {
        let dir = crate::testing::temp_dir("csv-missing");
        let path = dir.join("nope.csv");
        let err = load_csv(&path).unwrap_err();
        assert!(err.to_string().contains("nope.csv"), "got: {err}");
        let b = read_csv(SAMPLE.as_bytes()).unwrap();
        let bad = dir.join("no-such-dir").join("out.csv");
        let err = save_csv(&b, &bad).unwrap_err();
        assert!(err.to_string().contains("out.csv"), "got: {err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
