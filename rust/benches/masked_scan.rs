//! **Block-sketch masked-scan bench**: the sub-partition sketch hierarchy
//! against a block-blind baseline on the two workloads it exists for,
//! over a tiered dataset ~4× the memory budget (cold faults are real):
//!
//!   * **edge-heavy** — narrow windows that clip partitions at (and near)
//!     kernel-block boundaries. Interior blocks answer by merging their
//!     retained seal-time partials; only the ≤2 remainder blocks fold
//!     rows, and a window that lands exactly on the block grid never
//!     faults its partition at all.
//!   * **fraud-mix** — a CDR-style conjunction (`duration > 900 AND
//!     cost > 900`) where each rare condition clusters in a *different*
//!     block of most partitions. Partition-level zones pass both
//!     predicates, but block-level zones prune every block — the cold
//!     partition skips its segment bytes before fault-in. Only the few
//!     partitions where the conditions co-locate scan one block.
//!
//! Two arms per workload, identical queries, cold cache per pass:
//!   * block-blind   — `PlanOptions { block_pruning: false, .. }`
//!   * block-sketch  — the default plan
//! Answers must be bit-identical (the block partials and the scan fold
//! share `fold_stats_f32`; a pruned block's masked fold is the merge
//! identity), with fewer rows folded and strictly fewer segment bytes.
//!
//! Emits `BENCH_masked_scan.json` for the perf trajectory.
//!
//! Run: `cargo bench --bench masked_scan`
//! (OSEBA_MASKED_SCAN_BUDGET rescales; dataset is 4× the budget.)

mod common;

use oseba::bench::{bench, section, table, BenchConfig};
use oseba::config::{parse_bytes, BackendKind, ContextConfig};
use oseba::coordinator::{
    plan_query_opts, Coordinator, PhysicalPlan, PlanOptions, Query, QueryOutput,
};
use oseba::engine::Dataset;
use oseba::index::{ColumnPredicate, PredOp, RangeQuery};
use oseba::runtime::make_backend;
use oseba::storage::{BatchBuilder, Schema, BLOCK_ROWS};
use oseba::util::humansize;
use oseba::util::json::Json;

/// Three kernel blocks per partition: one spike block per condition plus
/// one interior block for coverage/co-location.
const BLOCKS_PER_PART: usize = 3;

fn coordinator(budget: usize) -> Coordinator {
    let mut cfg = common::app_cfg(BackendKind::Native);
    cfg.ctx = ContextConfig { num_workers: 4, memory_budget: Some(budget) };
    let be = make_backend(cfg.backend, &cfg.artifacts_dir).expect("backend");
    Coordinator::new(&cfg, be).expect("coordinator")
}

/// CDR-style batch: keys are the row index (step 1, so key windows map
/// onto exact row windows). Column 0 "duration" spikes past 900 only in
/// block 0 of each partition; column 1 "cost" spikes only in block 2.
/// In every 8th partition, block 1 holds rows where BOTH spike — the
/// actual fraud the conjunction is hunting.
fn cdr_batch(partitions: usize) -> oseba::storage::RecordBatch {
    let rows_per = BLOCKS_PER_PART * BLOCK_ROWS;
    let mut b = BatchBuilder::new(Schema::stock());
    for i in 0..partitions * rows_per {
        let (p, r) = (i / rows_per, i % rows_per);
        let mut duration = (r % 600) as f32;
        let mut cost = ((r * 7) % 600) as f32;
        if r < BLOCK_ROWS && r % 512 == 0 {
            duration = 901.0;
        }
        if r >= 2 * BLOCK_ROWS && r % 512 == 0 {
            cost = 905.0;
        }
        if p % 8 == 0 && (BLOCK_ROWS..2 * BLOCK_ROWS).contains(&r) && r % 1024 == 0 {
            duration = 950.0;
            cost = 960.0;
        }
        b.push(i as i64, &[duration, cost]);
    }
    b.finish().unwrap()
}

fn run_stats(
    c: &Coordinator,
    ds: &Dataset,
    plan: &PhysicalPlan,
    q: &Query,
) -> oseba::analysis::PeriodStats {
    match c.execute_physical(ds, plan, q).expect("execute") {
        QueryOutput::Stats(s) => s,
        _ => unreachable!(),
    }
}

struct Workload {
    name: &'static str,
    queries: Vec<Query>,
}

fn main() {
    let budget = std::env::var("OSEBA_MASKED_SCAN_BUDGET")
        .ok()
        .map(|v| parse_bytes(&v).expect("OSEBA_MASKED_SCAN_BUDGET"))
        .unwrap_or(8 << 20);
    let rows_per = BLOCKS_PER_PART * BLOCK_ROWS;
    let row_bytes = Schema::stock().row_bytes();
    let partitions = (4 * budget / (rows_per * row_bytes)).max(8);
    let rows = partitions * rows_per;
    let raw = rows * row_bytes;
    let dir =
        std::env::temp_dir().join(format!("oseba-masked-scan-bench-{}", std::process::id()));

    section(&format!(
        "Masked scans: {} tiered dataset under a {} budget ({} partitions x {} blocks)",
        humansize::bytes(raw),
        humansize::bytes(budget),
        partitions,
        BLOCKS_PER_PART
    ));

    let coord = coordinator(budget);
    let ds = coord
        .load_tiered(cdr_batch(partitions), partitions, &dir)
        .expect("tiered load");
    let store = ds.store().expect("tiered").clone();
    let index = coord
        .build_index(&ds, oseba::coordinator::IndexKind::Cias)
        .expect("index");

    // Edge-heavy: every partition gets a window starting one block in
    // (grid-aligned: fully covered, never faulted) and every other
    // partition also gets an off-grid window (one remainder block folds).
    let mut edge_queries = Vec::new();
    for p in 0..partitions {
        let base = (p * rows_per) as i64;
        edge_queries.push(Query::stats(
            RangeQuery { lo: base + BLOCK_ROWS as i64, hi: base + rows_per as i64 - 1 },
            0,
        ));
        if p % 2 == 0 {
            edge_queries.push(Query::stats(
                RangeQuery {
                    lo: base + BLOCK_ROWS as i64 + 200,
                    hi: base + rows_per as i64 - 1,
                },
                0,
            ));
        }
    }
    // Fraud-mix: the full-span conjunction, repeated so the wall-clock
    // arm measures more than one planning pass.
    let fraud_query = || {
        Query::stats(RangeQuery { lo: 0, hi: rows as i64 - 1 }, 0).filtered(vec![
            ColumnPredicate { column: 0, op: PredOp::Gt, value: 900.0 },
            ColumnPredicate { column: 1, op: PredOp::Gt, value: 900.0 },
        ])
    };
    let workloads = [
        Workload { name: "edge-heavy", queries: edge_queries },
        Workload { name: "fraud-mix", queries: (0..8).map(|_| fraud_query()).collect() },
    ];

    let blind = PlanOptions { block_pruning: false, ..PlanOptions::default() };
    let assisted = PlanOptions::default();

    let cfg = BenchConfig::from_env();
    let mut json_workloads = Vec::new();
    for w in &workloads {
        section(&format!("workload: {}", w.name));

        // Correctness first, cold cache: bit-identical answers per query.
        for q in &w.queries {
            let bp = plan_query_opts(&ds, index.as_ref(), q, blind).expect("plan");
            let ap = plan_query_opts(&ds, index.as_ref(), q, assisted).expect("plan");
            store.shrink(usize::MAX).expect("evict all");
            let want = run_stats(&coord, &ds, &bp, q);
            store.shrink(usize::MAX).expect("evict all");
            let got = run_stats(&coord, &ds, &ap, q);
            assert_eq!(got, want, "block sketches must not change answers ({})", w.name);
        }

        let mut results = Vec::new();
        let mut json_arms = Vec::new();
        for (name, opts) in [("block-blind", blind), ("block-sketch", assisted)] {
            let plans: Vec<(Query, PhysicalPlan)> = w
                .queries
                .iter()
                .map(|q| {
                    let p = plan_query_opts(&ds, index.as_ref(), q, opts).expect("plan");
                    (q.clone(), p)
                })
                .collect();
            let rows_folded: usize =
                plans.iter().map(|(_, p)| p.explain.estimated_rows).sum();
            let rows_avoided: usize =
                plans.iter().map(|(_, p)| p.explain.rows_avoided).sum();
            let blocks_covered: usize =
                plans.iter().map(|(_, p)| p.explain.blocks_covered).sum();
            let blocks_pruned: usize =
                plans.iter().map(|(_, p)| p.explain.blocks_pruned).sum();

            store.shrink(usize::MAX).expect("evict all");
            let before = store.counters();
            for (q, p) in &plans {
                run_stats(&coord, &ds, p, q);
            }
            let delta = store.counters().since(&before);

            let r = bench(&cfg, &format!("{} {name}", w.name), || {
                store.shrink(usize::MAX).expect("evict all");
                for (q, p) in &plans {
                    run_stats(&coord, &ds, p, q);
                }
            });
            println!(
                "  {name}: {} rows folded, {} avoided, {} blocks covered, {} pruned, {} faults, {} read",
                rows_folded,
                rows_avoided,
                blocks_covered,
                blocks_pruned,
                delta.faults,
                humansize::bytes(delta.segment_bytes_read)
            );
            json_arms.push(Json::obj(vec![
                ("name", Json::str(name)),
                ("rows_folded", Json::num(rows_folded as f64)),
                ("rows_avoided", Json::num(rows_avoided as f64)),
                ("blocks_covered", Json::num(blocks_covered as f64)),
                ("blocks_pruned", Json::num(blocks_pruned as f64)),
                ("faults", Json::num(delta.faults as f64)),
                ("segment_bytes_read", Json::num(delta.segment_bytes_read as f64)),
                ("queries", Json::num(w.queries.len() as f64)),
                ("secs_mean", Json::num(r.summary.mean)),
                ("secs_p50", Json::num(r.summary.p50)),
                ("secs_p95", Json::num(r.summary.p95)),
            ]));
            results.push(r);
        }
        println!("\n{}", table(&results));

        // The acceptance gate per workload: fewer rows folded, strictly
        // fewer segment bytes, same answers (asserted above).
        let (bl, sk) = (&json_arms[0], &json_arms[1]);
        let f = |j: &Json, k: &str| j.get(k).and_then(|v| v.as_f64()).unwrap();
        assert!(
            f(sk, "rows_folded") < f(bl, "rows_folded"),
            "{}: block sketches must fold fewer rows ({} vs {})",
            w.name,
            f(sk, "rows_folded"),
            f(bl, "rows_folded")
        );
        assert!(
            f(sk, "segment_bytes_read") < f(bl, "segment_bytes_read"),
            "{}: block sketches must read strictly fewer segment bytes ({} vs {})",
            w.name,
            f(sk, "segment_bytes_read"),
            f(bl, "segment_bytes_read")
        );
        assert!(f(sk, "blocks_covered") + f(sk, "blocks_pruned") > 0.0);

        json_workloads.push(Json::obj(vec![
            ("name", Json::str(w.name)),
            ("arms", Json::arr(json_arms)),
        ]));
    }

    common::write_bench_json(
        "masked_scan",
        Json::obj(vec![
            ("bench", Json::str("masked_scan")),
            ("raw_bytes", Json::num(raw as f64)),
            ("budget_bytes", Json::num(budget as f64)),
            ("partitions", Json::num(partitions as f64)),
            ("rows", Json::num(rows as f64)),
            ("workloads", Json::arr(json_workloads)),
        ]),
    );

    coord.context().unpersist(&ds);
    let _ = std::fs::remove_dir_all(&dir);
}
