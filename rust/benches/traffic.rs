//! Mixed-traffic harness: replay a configurable op mixture from the three
//! datagens against live server loops and report per-class latency
//! quantiles straight from the crate's own latency histograms.
//!
//! Three servers run concurrently, one per workload class:
//!
//! * `climate` — wide scans (~20% of the keyspace) over `temperature`;
//! * `stock`   — one-day windows over `price`;
//! * `cdr`     — point lookups over `duration` with a `where` predicate.
//!
//! Worker threads each hold one connection per server and draw ops from
//! the mixture with a seeded RNG, so a run is reproducible. Latencies are
//! recorded into per-class [`LatencyHistogram`]s (the same type the
//! server's `metrics` op serves) and merged across threads — this bench
//! dogfoods the observability layer it measures.
//!
//! Knobs (env): `OSEBA_TRAFFIC_OPS` total ops (default 600),
//! `OSEBA_TRAFFIC_CONC` worker threads (default 4), `OSEBA_TRAFFIC_ROWS`
//! rows per dataset (default 60_000), `OSEBA_TRAFFIC_MIX` weights as
//! `climate:stock:cdr` (default `1:1:1`), `OSEBA_TRAFFIC_FAULT_OPS` /
//! `OSEBA_TRAFFIC_FAULT_PROB` for the injected-fault arm (default
//! 200 ops at 15% per-read error probability; `0` ops disables it).
//!
//! Emits `BENCH_traffic.json` with p50/p99/mean latency, error count,
//! faults and bytes materialized per op class, plus a `faulted` object:
//! the same stats op shape against a tiered store whose segment reads
//! fail probabilistically, reporting error rate, latency under faults,
//! and the store's retry/quarantine counters (DESIGN.md §16).

mod common;

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};

use oseba::config::AppConfig;
use oseba::coordinator::{Coordinator, IndexKind};
use oseba::datagen::{CdrGen, ClimateGen, StockGen};
use oseba::metrics::{LatencyHistogram, Timer};
use oseba::runtime::NativeBackend;
use oseba::server::QueryServer;
use oseba::util::json::Json;
use oseba::util::rng::Xoshiro256;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Injected-fault arm: narrow stats scans against a tiered store whose
/// segment reads error with probability `prob`, exercising the store's
/// retry path end to end. Every op starts cold (`release_resident`), so
/// every op pays the faulty read path; the returned object carries the
/// observed error rate, the latency quantiles *under faults*, and the
/// store's recovery counters.
fn faulted_arm(rows: usize, ops: usize, prob: f64) -> Json {
    use oseba::engine::Lineage;
    use oseba::index::RangeQuery;
    use oseba::store::fault::{site, FaultInjector, FaultKind, FaultRule};
    use oseba::store::{StoreIo, TieredStore};

    let dir = std::env::temp_dir()
        .join(format!("oseba-traffic-faults-{}", std::process::id()));
    // Build and save the store over clean I/O — faults arm on reads only.
    let batch = ClimateGen::default().generate(rows);
    {
        let store = TieredStore::create_with(
            &dir,
            batch.schema.clone(),
            oseba::engine::MemoryTracker::unbounded(),
            StoreIo::disabled(),
        )
        .expect("create store");
        let per = rows.div_ceil(16);
        for part in oseba::storage::partition_batch_uniform(&batch, per).expect("partition") {
            store.insert(part).expect("insert");
        }
        store.save().expect("save");
    }

    let cfg = AppConfig { cluster_workers: 2, ..Default::default() };
    let coord = Coordinator::new(&cfg, Arc::new(NativeBackend)).expect("coordinator");
    let injector = Arc::new(FaultInjector::new(0xFA17));
    injector.add_rule(FaultRule::new(site::SEGMENT_READ, FaultKind::Error).prob(prob));
    let tracker = coord.context().block_manager().tracker();
    let (store, index) =
        TieredStore::open_with(&dir, tracker, StoreIo::with(Arc::clone(&injector)))
            .expect("open store");
    let store = Arc::new(store);
    let ds = coord
        .context()
        .adopt_tiered(
            store.schema().clone(),
            Arc::clone(&store),
            Lineage::Source { name: "traffic-faults".into() },
        )
        .expect("adopt store");
    coord.cluster().ensure_partitions(ds.num_partitions());

    let key_hi = ds.key_max().unwrap_or(0);
    let before = store.counters();
    let hist = LatencyHistogram::new();
    let mut errors = 0u64;
    let mut rng = Xoshiro256::seeded(0xFA17_7AFF);
    for _ in 0..ops {
        // Narrow scans off the partition grid: edge slices cannot be
        // answered from sketches, so every op reads segment bytes.
        let span = (key_hi / 64).max(1);
        let lo = rng.below((key_hi - span).max(0) as u64 + 1) as i64;
        let q = RangeQuery { lo, hi: lo + span };
        // Cold-start every op — otherwise the first fault-in pins the
        // partitions resident and later ops never touch the fault sites.
        store.release_resident();
        let t = Timer::start();
        if coord.analyze_period_oseba(&ds, &index, q, 0).is_err() {
            errors += 1;
        }
        hist.record_duration(t.elapsed());
    }
    let d = store.counters().since(&before);
    let snap = hist.snapshot();
    println!(
        "  faulted  {:>6} ops  p50 {:>10.6}s  p99 {:>10.6}s  {} errors  {} retries ({} recovered)",
        ops,
        snap.p50() as f64 / 1e9,
        snap.p99() as f64 / 1e9,
        errors,
        d.io_retries,
        d.io_retry_successes,
    );
    let _ = std::fs::remove_dir_all(&dir);
    Json::obj(vec![
        ("read_error_prob", Json::num(prob)),
        ("ops", Json::num(ops as f64)),
        ("errors", Json::num(errors as f64)),
        ("error_rate", Json::num(errors as f64 / (ops.max(1)) as f64)),
        ("p50", Json::num(snap.p50() as f64 / 1e9)),
        ("p99", Json::num(snap.p99() as f64 / 1e9)),
        ("mean_secs", Json::num(snap.mean_secs())),
        ("io_retries", Json::num(d.io_retries as f64)),
        ("io_retry_successes", Json::num(d.io_retry_successes as f64)),
        ("partitions_quarantined", Json::num(d.quarantined as f64)),
        ("recovery_secs", Json::num(d.recovery_nanos as f64 / 1e9)),
    ])
}

/// One workload class: a dedicated server plus the request generator for
/// its op shape.
struct OpClass {
    name: &'static str,
    addr: std::net::SocketAddr,
    /// Inclusive key range of the loaded dataset.
    key_hi: i64,
    key_step: i64,
    handle: std::thread::JoinHandle<()>,
    hist: Arc<LatencyHistogram>,
    errors: Arc<AtomicU64>,
}

impl OpClass {
    /// A request line for this class drawn from `rng`.
    fn request(&self, rng: &mut Xoshiro256) -> String {
        match self.name {
            "climate" => {
                // Wide scan: ~20% of the keyspace, random offset.
                let span = self.key_hi / 5;
                let lo = rng.below((self.key_hi - span) as u64 + 1) as i64;
                format!(
                    r#"{{"op":"stats","lo":{lo},"hi":{},"column":"temperature"}}"#,
                    lo + span
                )
            }
            "stock" => {
                // One trading day of per-minute bars.
                let span = 86_400.min(self.key_hi);
                let lo = rng.below((self.key_hi - span) as u64 + 1) as i64;
                format!(r#"{{"op":"stats","lo":{lo},"hi":{},"column":"price"}}"#, lo + span)
            }
            _ => {
                // Point lookup on the key grid, predicate pushed down.
                let key = rng.below((self.key_hi / self.key_step) as u64 + 1) as i64
                    * self.key_step;
                format!(
                    r#"{{"op":"stats","lo":{key},"hi":{key},"column":"duration","where":"duration >= 0"}}"#
                )
            }
        }
    }
}

/// Start one server over `batch`-shaped data and return its class handle.
fn start_class(
    name: &'static str,
    batch: oseba::storage::RecordBatch,
    key_step: i64,
) -> OpClass {
    let cfg = AppConfig { cluster_workers: 2, ..Default::default() };
    let coord = Coordinator::new(&cfg, Arc::new(NativeBackend)).expect("coordinator");
    let ds = coord.load(batch, 16).expect("load");
    let key_hi = ds.key_max().unwrap_or(0);
    let server =
        QueryServer::new(Arc::new(coord), ds, IndexKind::Cias).expect("server");
    let (addr_tx, addr_rx) = mpsc::channel();
    let handle = std::thread::spawn(move || {
        server.serve("127.0.0.1:0", |a| addr_tx.send(a).unwrap()).expect("serve");
    });
    let addr = addr_rx.recv().expect("bound address");
    OpClass {
        name,
        addr,
        key_hi,
        key_step,
        handle,
        hist: Arc::new(LatencyHistogram::new()),
        errors: Arc::new(AtomicU64::new(0)),
    }
}

/// One line-delimited JSON round trip.
fn ask(
    stream: &mut TcpStream,
    reader: &mut BufReader<TcpStream>,
    req: &str,
) -> Json {
    stream.write_all(req.as_bytes()).expect("send");
    stream.write_all(b"\n").expect("send");
    let mut line = String::new();
    reader.read_line(&mut line).expect("recv");
    Json::parse(line.trim()).expect("response json")
}

fn main() {
    let ops = env_usize("OSEBA_TRAFFIC_OPS", 600);
    let conc = env_usize("OSEBA_TRAFFIC_CONC", 4).max(1);
    let rows = env_usize("OSEBA_TRAFFIC_ROWS", 60_000);
    let mix_spec = std::env::var("OSEBA_TRAFFIC_MIX").unwrap_or_else(|_| "1:1:1".into());
    let weights: Vec<u64> = mix_spec
        .split(':')
        .map(|w| w.parse().expect("OSEBA_TRAFFIC_MIX must be w:w:w"))
        .collect();
    assert_eq!(weights.len(), 3, "OSEBA_TRAFFIC_MIX must be climate:stock:cdr");
    let total_weight: u64 = weights.iter().sum();
    assert!(total_weight > 0, "OSEBA_TRAFFIC_MIX must have a non-zero weight");

    println!("traffic: {ops} ops, {conc} workers, {rows} rows/class, mix {mix_spec}");
    let classes = Arc::new([
        start_class("climate", ClimateGen::default().generate(rows), 3_600),
        start_class("stock", StockGen::default().generate(rows), 60),
        start_class("cdr", CdrGen::default().generate(rows), 30),
    ]);

    let wall = Timer::start();
    let per_worker = ops.div_ceil(conc);
    let workers: Vec<_> = (0..conc)
        .map(|w| {
            let classes = Arc::clone(&classes);
            std::thread::spawn(move || {
                let mut rng = Xoshiro256::seeded(0x7AFF1C + w as u64);
                // One long-lived connection per server, like a real client.
                let mut conns: Vec<(TcpStream, BufReader<TcpStream>)> = classes
                    .iter()
                    .map(|c| {
                        let s = TcpStream::connect(c.addr).expect("connect");
                        let r = BufReader::new(s.try_clone().expect("clone"));
                        (s, r)
                    })
                    .collect();
                for _ in 0..per_worker {
                    let mut pick = rng.below(total_weight);
                    let mut idx = 0;
                    while pick >= weights[idx] {
                        pick -= weights[idx];
                        idx += 1;
                    }
                    let class = &classes[idx];
                    let req = class.request(&mut rng);
                    let (stream, reader) = &mut conns[idx];
                    let t = Timer::start();
                    let resp = ask(stream, reader, &req);
                    class.hist.record_duration(t.elapsed());
                    if resp.get("ok") != Some(&Json::Bool(true)) {
                        class.errors.fetch_add(1, Ordering::Relaxed);
                        eprintln!("{} error: {resp}", class.name);
                    }
                }
            })
        })
        .collect();
    for w in workers {
        w.join().expect("worker");
    }
    let wall_secs = wall.secs();

    // Drain per-class engine counters over the wire (the servers own
    // their coordinators), then shut each one down.
    let mut class_docs = Vec::new();
    let Ok(classes) = Arc::try_unwrap(classes) else {
        panic!("workers joined; no Arc clones remain")
    };
    for class in classes {
        let mut stream = TcpStream::connect(class.addr).expect("connect");
        let mut reader = BufReader::new(stream.try_clone().expect("clone"));
        let info = ask(&mut stream, &mut reader, r#"{"op":"info"}"#);
        let counters = info.get("counters").expect("info counters");
        let bytes = counters.get("bytes_materialized").and_then(Json::as_f64).unwrap_or(0.0);
        // In-memory datasets never fault; a tiered deployment surfaces
        // the same leaf with real traffic.
        let faults = info.get("faults").and_then(Json::as_f64).unwrap_or(0.0);
        ask(&mut stream, &mut reader, r#"{"op":"shutdown"}"#);
        class.handle.join().expect("server thread");

        let snap = class.hist.snapshot();
        let errors = class.errors.load(Ordering::Relaxed);
        println!(
            "  {:<8} {:>6} ops  p50 {:>10.6}s  p99 {:>10.6}s  {} errors",
            class.name,
            snap.count(),
            snap.p50() as f64 / 1e9,
            snap.p99() as f64 / 1e9,
            errors,
        );
        class_docs.push(Json::obj(vec![
            ("name", Json::str(class.name)),
            ("ops", Json::num(snap.count() as f64)),
            ("errors", Json::num(errors as f64)),
            ("p50", Json::num(snap.p50() as f64 / 1e9)),
            ("p99", Json::num(snap.p99() as f64 / 1e9)),
            ("mean_secs", Json::num(snap.mean_secs())),
            ("faults", Json::num(faults)),
            ("bytes_selected", Json::num(bytes)),
        ]));
    }

    let done = per_worker * conc;
    println!(
        "traffic: {done} ops in {wall_secs:.3}s ({:.0} ops/s)",
        done as f64 / wall_secs.max(1e-9)
    );

    let fault_ops = env_usize("OSEBA_TRAFFIC_FAULT_OPS", 200);
    let fault_prob = env_f64("OSEBA_TRAFFIC_FAULT_PROB", 0.15);
    let faulted = if fault_ops > 0 {
        faulted_arm(rows, fault_ops, fault_prob)
    } else {
        Json::Null
    };

    common::write_bench_json(
        "traffic",
        Json::obj(vec![
            ("bench", Json::str("traffic")),
            ("ops", Json::num(done as f64)),
            ("concurrency", Json::num(conc as f64)),
            ("rows_per_class", Json::num(rows as f64)),
            ("wall_secs", Json::num(wall_secs)),
            ("classes", Json::arr(class_docs)),
            ("faulted", faulted),
        ]),
    );
}
