//! `RecordBatch`: the raw, unpartitioned columnar loading unit produced by
//! the data generators and consumed by `Dataset::from_batch`.

use crate::error::{OsebaError, Result};
use crate::storage::schema::Schema;

/// A columnar batch of rows sorted by key.
#[derive(Clone, Debug)]
pub struct RecordBatch {
    /// The batch's column schema.
    pub schema: Schema,
    /// Ordering keys, non-decreasing. `len == rows`.
    pub keys: Vec<i64>,
    /// One f32 vector per schema column, each `len == rows`.
    pub columns: Vec<Vec<f32>>,
}

impl RecordBatch {
    /// Validate invariants: column arity/lengths match, keys sorted.
    pub fn new(schema: Schema, keys: Vec<i64>, columns: Vec<Vec<f32>>) -> Result<RecordBatch> {
        if columns.len() != schema.width() {
            return Err(OsebaError::Schema(format!(
                "expected {} columns, got {}",
                schema.width(),
                columns.len()
            )));
        }
        for (i, c) in columns.iter().enumerate() {
            if c.len() != keys.len() {
                return Err(OsebaError::Schema(format!(
                    "column {i} has {} rows, keys have {}",
                    c.len(),
                    keys.len()
                )));
            }
        }
        if keys.windows(2).any(|w| w[0] > w[1]) {
            return Err(OsebaError::Schema("keys not sorted".into()));
        }
        Ok(RecordBatch { schema, keys, columns })
    }

    /// Number of rows in the batch.
    pub fn rows(&self) -> usize {
        self.keys.len()
    }

    /// Raw (unpadded) byte footprint — the "raw input data" of Fig 4.
    pub fn raw_bytes(&self) -> usize {
        self.rows() * self.schema.row_bytes()
    }

    /// Column view by name.
    pub fn column(&self, name: &str) -> Result<&[f32]> {
        Ok(&self.columns[self.schema.column_index(name)?])
    }
}

/// Incremental row-wise builder used by the data generators.
pub struct BatchBuilder {
    schema: Schema,
    keys: Vec<i64>,
    columns: Vec<Vec<f32>>,
}

impl BatchBuilder {
    /// An empty builder for `schema`.
    pub fn new(schema: Schema) -> BatchBuilder {
        let width = schema.width();
        BatchBuilder { schema, keys: Vec::new(), columns: vec![Vec::new(); width] }
    }

    /// An empty builder with `rows` preallocated per column.
    pub fn with_capacity(schema: Schema, rows: usize) -> BatchBuilder {
        let width = schema.width();
        BatchBuilder {
            schema,
            keys: Vec::with_capacity(rows),
            columns: vec![Vec::with_capacity(rows); width],
        }
    }

    /// Append one row; `values` must match the schema width.
    pub fn push(&mut self, key: i64, values: &[f32]) {
        debug_assert_eq!(values.len(), self.columns.len());
        self.keys.push(key);
        for (col, &v) in self.columns.iter_mut().zip(values) {
            col.push(v);
        }
    }

    /// Rows pushed so far.
    pub fn rows(&self) -> usize {
        self.keys.len()
    }

    /// Most recently pushed key (the CSV loader's sortedness check).
    pub fn last_key(&self) -> Option<&i64> {
        self.keys.last()
    }

    /// Finish, validating the batch invariants.
    pub fn finish(self) -> Result<RecordBatch> {
        RecordBatch::new(self.schema, self.keys, self.columns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batch3() -> RecordBatch {
        let mut b = BatchBuilder::new(Schema::stock());
        b.push(10, &[1.0, 100.0]);
        b.push(20, &[2.0, 200.0]);
        b.push(30, &[3.0, 300.0]);
        b.finish().unwrap()
    }

    #[test]
    fn builder_roundtrip() {
        let rb = batch3();
        assert_eq!(rb.rows(), 3);
        assert_eq!(rb.column("price").unwrap(), &[1.0, 2.0, 3.0]);
        assert_eq!(rb.column("volume").unwrap(), &[100.0, 200.0, 300.0]);
        assert_eq!(rb.keys, vec![10, 20, 30]);
    }

    #[test]
    fn raw_bytes() {
        assert_eq!(batch3().raw_bytes(), 3 * 16);
    }

    #[test]
    fn rejects_unsorted_keys() {
        let s = Schema::stock();
        let r = RecordBatch::new(s, vec![2, 1], vec![vec![0.0; 2], vec![0.0; 2]]);
        assert!(r.is_err());
    }

    #[test]
    fn rejects_ragged_columns() {
        let s = Schema::stock();
        let r = RecordBatch::new(s.clone(), vec![1, 2], vec![vec![0.0; 2], vec![0.0; 3]]);
        assert!(r.is_err());
        let r = RecordBatch::new(s, vec![1, 2], vec![vec![0.0; 2]]);
        assert!(r.is_err());
    }

    #[test]
    fn allows_duplicate_keys() {
        let s = Schema::stock();
        let r = RecordBatch::new(s, vec![5, 5], vec![vec![0.0; 2], vec![0.0; 2]]);
        assert!(r.is_ok());
    }

    #[test]
    fn unknown_column_errors() {
        assert!(batch3().column("nope").is_err());
    }
}
