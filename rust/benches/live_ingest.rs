//! **Live-ingest bench**: sustained append throughput while concurrent
//! selective queries run against epoch-pinned snapshots, plus the
//! index-maintenance cost of the incremental path (O(1) `append_meta` /
//! ASL absorption, occasional rebuild) against a *reload-per-epoch*
//! baseline that rebuilds the super index from scratch every time a
//! partition is published.
//!
//! Run: `cargo bench --bench live_ingest`
//! (`OSEBA_BYTES` rescales the ingested volume.)

mod common;

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

use oseba::bench::{bench, section, table, BenchConfig};
use oseba::config::parse_bytes;
use oseba::datagen::ClimateGen;
use oseba::engine::LiveConfig;
use oseba::index::{extract_meta, Cias, RangeQuery};
use oseba::ingest::{chunk_batch, LiveIngestor};
use oseba::storage::Schema;
use oseba::util::humansize;
use oseba::util::rng::Xoshiro256;

const ROWS_PER_PART: usize = 4096;
/// Every HOLD_EVERY-th partition-aligned block arrives late (out of
/// order), exercising ASL absorption and the bounded rebuild policy.
const HOLD_EVERY: usize = 9;

fn main() {
    let raw = std::env::var("OSEBA_BYTES")
        .ok()
        .map(|v| parse_bytes(&v).expect("OSEBA_BYTES"))
        .unwrap_or(8 << 20);
    let batch = ClimateGen::default().generate_bytes(raw);
    let total_rows = batch.rows();
    let blocks: Vec<_> = chunk_batch(&batch, ROWS_PER_PART);
    let n_blocks = blocks.len();

    section(&format!(
        "Live ingest: {} rows ({}) in {} partition-aligned blocks, every {}th late",
        total_rows,
        humansize::bytes(batch.raw_bytes()),
        n_blocks,
        HOLD_EVERY
    ));

    // ---- sustained append + concurrent snapshot-pinned queries ---------
    let coord = common::make_coord(oseba::config::BackendKind::Native);
    let live = coord
        .create_live(
            Schema::climate(),
            LiveConfig { rows_per_partition: ROWS_PER_PART, max_asl: 8 },
        )
        .expect("live dataset");

    let key_span = batch.keys.last().copied().unwrap_or(1);
    let done = AtomicBool::new(false);
    let queries_ok = AtomicUsize::new(0);
    let queries_empty = AtomicUsize::new(0);

    let t0 = std::time::Instant::now();
    let ingest_secs = std::thread::scope(|scope| {
        let (coord_ref, live_ref) = (&coord, &*live);
        let (done_ref, ok_ref, empty_ref) = (&done, &queries_ok, &queries_empty);
        scope.spawn(move || {
            // Interactive readers: narrow selective queries against
            // whatever epoch is current, for the whole ingest duration.
            let mut rng = Xoshiro256::seeded(42);
            while !done_ref.load(Ordering::SeqCst) {
                let lo = (rng.next_f64() * key_span as f64) as i64;
                let q = RangeQuery { lo, hi: lo + key_span / 64 };
                match coord_ref.analyze_live(live_ref, q, 0) {
                    Ok((stats, _epoch)) => {
                        assert!(stats.count > 0);
                        ok_ref.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(_) => {
                        // Nothing sealed yet / range not yet ingested.
                        empty_ref.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
        });

        // Writer: stream blocks through the long-lived ingestor, holding
        // back every HOLD_EVERY-th interior block for late delivery.
        let ing = LiveIngestor::spawn(Arc::clone(&live), 4);
        let mut late = Vec::new();
        for (b, chunk) in blocks.iter().enumerate() {
            if b > 0 && b + 1 < n_blocks && b % HOLD_EVERY == 0 {
                late.push(chunk.clone());
                continue;
            }
            ing.send(chunk.clone()).expect("send");
        }
        let sent = ing.finish().expect("ingest pipeline");
        // Late blocks arrive out of order, straight into the ASL.
        let mut rows = sent;
        for chunk in late.into_iter().rev() {
            rows += chunk.rows();
            live.append(chunk).expect("late append");
        }
        let secs = t0.elapsed().as_secs_f64();
        assert_eq!(rows, total_rows);
        done.store(true, Ordering::SeqCst);
        secs
    });

    let snap = coord.snapshot_live(&live);
    let c = live.counters();
    assert_eq!(snap.rows(), total_rows, "every appended row is visible");
    println!(
        "ingested {} rows in {} -> {:.1}M rows/s with {} concurrent queries served \
         ({} before data arrived)",
        total_rows,
        humansize::secs(ingest_secs),
        total_rows as f64 / ingest_secs / 1e6,
        queries_ok.load(Ordering::Relaxed),
        queries_empty.load(Ordering::Relaxed),
    );
    println!(
        "index maintenance: {} O(1) appends, {} ASL-absorbed (late), {} rebuilds, \
         final asl {} over {} partitions (epoch {})",
        c.index_appends, c.asl_absorbed, c.rebuilds, c.asl_len, c.sealed_partitions, c.epoch
    );
    if n_blocks > HOLD_EVERY + 1 {
        assert!(c.asl_absorbed > 0, "late blocks exercise the ASL");
    }
    assert_eq!(c.sealed_partitions, n_blocks);

    // Final correctness spot-check: a full-span query sees every row.
    let full = RangeQuery { lo: 0, hi: i64::MAX };
    let (stats, _) = coord.analyze_live(&live, full, 0).expect("full-span query");
    assert_eq!(stats.count as usize, total_rows);

    // ---- incremental maintenance vs reload-per-epoch baseline ----------
    section("index maintenance: incremental vs reload-per-epoch");
    // Replay the maintenance work over the final partition set in key
    // order (the in-order arrival schedule both strategies would see).
    let mut metas = extract_meta(snap.dataset().partitions());
    metas.sort_by_key(|m| m.key_min);
    for (i, m) in metas.iter_mut().enumerate() {
        m.id = i;
    }
    let n = metas.len();
    let cfg = BenchConfig::from_env();
    let mut results = Vec::new();
    results.push(bench(&cfg, "incremental (append_meta per epoch)", || {
        let mut ix = Cias::from_meta(vec![metas[0]]).expect("seed index");
        for &m in &metas[1..] {
            ix.append_meta(m).expect("append");
        }
        assert_eq!(ix.regular_parts() + ix.asl_len(), n);
    }));
    results.push(bench(&cfg, "reload-per-epoch (from_meta per epoch)", || {
        let mut last = None;
        for i in 1..=n {
            last = Some(Cias::from_meta(metas[..i].to_vec()).expect("rebuild"));
        }
        let ix = last.unwrap();
        assert_eq!(ix.regular_parts() + ix.asl_len(), n);
    }));
    println!("{}", table(&results));
    let inc = results[0].summary.mean;
    let reload = results[1].summary.mean;
    println!(
        "incremental {} vs reload-per-epoch {} -> {:.1}x cheaper over {n} epochs",
        humansize::secs(inc),
        humansize::secs(reload),
        reload / inc.max(1e-12)
    );
    assert!(
        inc < reload,
        "incremental maintenance ({inc}) must beat reload-per-epoch ({reload})"
    );
    println!("\nshape check: appends absorbed incrementally ✓, snapshots always whole ✓");

    use oseba::util::json::Json;
    common::write_bench_json(
        "live_ingest",
        Json::obj(vec![
            ("bench", Json::str("live_ingest")),
            ("rows", Json::num(total_rows as f64)),
            ("ingest_secs", Json::num(ingest_secs)),
            ("rows_per_sec", Json::num(total_rows as f64 / ingest_secs)),
            (
                "concurrent_queries_served",
                Json::num(queries_ok.load(Ordering::Relaxed) as f64),
            ),
            ("index_appends", Json::num(c.index_appends as f64)),
            ("asl_absorbed", Json::num(c.asl_absorbed as f64)),
            ("rebuilds", Json::num(c.rebuilds as f64)),
            ("incremental_maintenance_secs", Json::num(inc)),
            ("reload_per_epoch_secs", Json::num(reload)),
        ]),
    );
    live.close();
}
