//! `oseba-lint`: repo-native static analysis for the Oseba tree.
//!
//! The compiler cannot check Oseba's cross-file invariants — that the
//! serving path never panics a worker thread, that every counter a layer
//! increments is actually surfaced by the server, that every manifest
//! version the writer can emit is handled by the reader. This binary
//! parses `rust/src` at the token/structure level (a masking lexer, not a
//! full grammar) and enforces those rules. It is dependency-free by the
//! same vendoring policy as the crate it checks.
//!
//! Rules (each one a class; see DESIGN.md §12):
//!
//! | rule              | what it rejects                                              |
//! |-------------------|--------------------------------------------------------------|
//! | `no-unwrap`       | `.unwrap()` / `.expect(..)` outside test/bench scope          |
//! | `no-panic`        | `panic!` / `unreachable!` / `todo!` / `unimplemented!`        |
//! | `no-lock-unwrap`  | `.lock().unwrap()` (poisoning cascade) specifically           |
//! | `error-variants`  | an `OsebaError` variant no code path constructs               |
//! | `counters-surfaced` | an `EngineCounters`/`LiveCounters` field the server never   |
//! |                   | surfaces (or that nothing updates)                            |
//! | `manifest-versions` | a manifest version the reader or writer does not handle     |
//! | `bench-json`      | a bench target that never emits its `BENCH_*.json` artifact   |
//! | `store-io-wrapped` | raw `std::fs` / `File` / `OpenOptions` in `store/` outside   |
//! |                   | `fault.rs` (bypassing the failpoint-instrumented `StoreIo`)   |
//!
//! Scope: site rules (`no-unwrap`, `no-panic`, `no-lock-unwrap`) skip
//! `#[cfg(test)]` regions and the `testing/` + `datagen/` modules; benches
//! are only scanned by `bench-json`. A site can be exempted with a
//! justified comment on the same or the preceding line:
//!
//! ```text
//! // lint: allow(no-unwrap) -- mutex guards no invariant; poisoning is impossible here
//! ```
//!
//! An allow comment without a `-- <reason>` tail is itself a violation.
//!
//! Usage: `cargo run -p oseba-lint` (workspace root), `--root <dir>` to
//! point at another tree, `--self-test` to run every rule against its
//! seeded violation fixture and require that it fires.

use std::fmt;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut self_test = false;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => match args.next() {
                Some(dir) => root = Some(PathBuf::from(dir)),
                None => {
                    eprintln!("--root requires a directory argument");
                    return ExitCode::from(2);
                }
            },
            "--self-test" => self_test = true,
            "--help" | "-h" => {
                eprintln!("usage: oseba-lint [--root <repo-root>] [--self-test]");
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("unknown argument: {other}");
                return ExitCode::from(2);
            }
        }
    }
    if self_test {
        return run_self_test();
    }
    let root = root.unwrap_or_else(|| PathBuf::from("."));
    match lint_tree(&root) {
        Ok(findings) => {
            for f in &findings {
                println!("{f}");
            }
            if findings.is_empty() {
                println!("oseba-lint: clean");
                ExitCode::SUCCESS
            } else {
                println!("oseba-lint: {} finding(s)", findings.len());
                ExitCode::FAILURE
            }
        }
        Err(e) => {
            eprintln!("oseba-lint: {e}");
            ExitCode::from(2)
        }
    }
}

/// Run the full rule set over each seeded violation fixture and require
/// that the fixture's own rule class fires. This is how CI proves the
/// lint still has teeth: a rule that silently stopped matching fails here.
fn run_self_test() -> ExitCode {
    let fixtures = Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures");
    let mut ok = true;
    for rule in Rule::ALL {
        let dir = fixtures.join(rule.name());
        match lint_tree(&dir) {
            Ok(findings) => {
                let fired = findings.iter().any(|f| f.rule == *rule);
                println!(
                    "self-test {:>18}: {} ({} finding(s))",
                    rule.name(),
                    if fired { "fires" } else { "MISSED" },
                    findings.len()
                );
                ok &= fired;
            }
            Err(e) => {
                println!("self-test {:>18}: ERROR {e}", rule.name());
                ok = false;
            }
        }
    }
    if ok {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

// ---------------------------------------------------------------------------
// Rules and findings
// ---------------------------------------------------------------------------

/// One rule class. Every class is self-tested against a seeded violation
/// fixture under `tools/lint/fixtures/<name>/`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Rule {
    NoUnwrap,
    NoPanic,
    NoLockUnwrap,
    ErrorVariants,
    CountersSurfaced,
    ManifestVersions,
    BenchJson,
    StoreIoWrapped,
}

impl Rule {
    const ALL: &'static [Rule] = &[
        Rule::NoUnwrap,
        Rule::NoPanic,
        Rule::NoLockUnwrap,
        Rule::ErrorVariants,
        Rule::CountersSurfaced,
        Rule::ManifestVersions,
        Rule::BenchJson,
        Rule::StoreIoWrapped,
    ];

    fn name(&self) -> &'static str {
        match self {
            Rule::NoUnwrap => "no-unwrap",
            Rule::NoPanic => "no-panic",
            Rule::NoLockUnwrap => "no-lock-unwrap",
            Rule::ErrorVariants => "error-variants",
            Rule::CountersSurfaced => "counters-surfaced",
            Rule::ManifestVersions => "manifest-versions",
            Rule::BenchJson => "bench-json",
            Rule::StoreIoWrapped => "store-io-wrapped",
        }
    }

    fn from_name(s: &str) -> Option<Rule> {
        Rule::ALL.iter().copied().find(|r| r.name() == s)
    }
}

/// One violation: where, which rule, and why.
#[derive(Debug)]
struct Finding {
    rule: Rule,
    file: PathBuf,
    /// 1-based; 0 for whole-file/whole-tree findings.
    line: usize,
    msg: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}",
            self.file.display(),
            self.line,
            self.rule.name(),
            self.msg
        )
    }
}

// ---------------------------------------------------------------------------
// Tree walking
// ---------------------------------------------------------------------------

/// A parsed source file: path (relative to the scanned root), raw text,
/// and the comment/string-masked code view.
struct SourceFile {
    rel: PathBuf,
    raw: String,
    masked: Masked,
    /// Per-line flag: line lies inside a `#[cfg(test)]` region.
    in_test: Vec<bool>,
}

fn lint_tree(root: &Path) -> Result<Vec<Finding>, String> {
    let src_root = root.join("rust").join("src");
    let bench_root = root.join("rust").join("benches");
    let mut files = Vec::new();
    collect_rs(&src_root, &src_root, &mut files)?;
    files.sort();
    let mut parsed = Vec::new();
    for path in &files {
        let raw = std::fs::read_to_string(src_root.join(path))
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let masked = mask_source(&raw);
        let in_test = test_region_lines(&masked.code);
        parsed.push(SourceFile { rel: path.clone(), raw, masked, in_test });
    }

    let mut findings = Vec::new();
    for sf in &parsed {
        findings.extend(site_rules(sf));
        findings.extend(rule_store_io(sf));
    }
    findings.extend(rule_error_variants(&parsed));
    findings.extend(rule_counters_surfaced(&parsed));
    findings.extend(rule_manifest_versions(&parsed));
    findings.extend(rule_bench_json(&bench_root)?);
    findings.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    Ok(findings)
}

fn collect_rs(dir: &Path, root: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        // A fixture tree may omit rust/src entirely; an empty tree is
        // simply a tree with no site findings (tree rules still report
        // their missing anchors).
        Err(_) => return Ok(()),
    };
    for entry in entries {
        let entry = entry.map_err(|e| format!("walk {}: {e}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, root, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            let rel = path
                .strip_prefix(root)
                .map_err(|e| format!("relativize {}: {e}", path.display()))?;
            out.push(rel.to_path_buf());
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Masking lexer
// ---------------------------------------------------------------------------

/// A source view with comments and literals blanked out of `code`
/// (newlines preserved, so byte offsets map to the same lines), plus the
/// comments and string literals collected per line for the rules that
/// need them (allow-comments; server surfacing keys).
struct Masked {
    code: String,
    /// `(0-based line, comment text including the leading slashes)`.
    comments: Vec<(usize, String)>,
    /// `(0-based line, string literal content without quotes)`.
    strings: Vec<(usize, String)>,
}

fn mask_source(src: &str) -> Masked {
    let b = src.as_bytes();
    let mut code = vec![b' '; b.len()];
    let mut comments = Vec::new();
    let mut strings = Vec::new();
    let mut line = 0usize;
    let mut i = 0usize;
    while i < b.len() {
        let c = b[i];
        if c == b'\n' {
            code[i] = b'\n';
            line += 1;
            i += 1;
        } else if c == b'/' && b.get(i + 1) == Some(&b'/') {
            let start = i;
            while i < b.len() && b[i] != b'\n' {
                i += 1;
            }
            comments.push((line, src[start..i].to_string()));
        } else if c == b'/' && b.get(i + 1) == Some(&b'*') {
            let mut depth = 1u32;
            i += 2;
            while i < b.len() && depth > 0 {
                if b[i] == b'\n' {
                    code[i] = b'\n';
                    line += 1;
                    i += 1;
                } else if b[i] == b'/' && b.get(i + 1) == Some(&b'*') {
                    depth += 1;
                    i += 2;
                } else if b[i] == b'*' && b.get(i + 1) == Some(&b'/') {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
        } else if let Some(skip) = raw_string_len(b, i) {
            let start_line = line;
            // Preserve newlines inside the masked span so offsets keep
            // mapping to the right lines.
            for (off, &rb) in b[i..i + skip].iter().enumerate() {
                if rb == b'\n' {
                    code[i + off] = b'\n';
                    line += 1;
                }
            }
            strings.push((start_line, src[i..i + skip].to_string()));
            i += skip;
        } else if c == b'"' {
            let start_line = line;
            let start = i;
            i += 1;
            while i < b.len() {
                if b[i] == b'\\' {
                    // An escape may hide a newline (line continuation).
                    if b.get(i + 1) == Some(&b'\n') {
                        code[i + 1] = b'\n';
                        line += 1;
                    }
                    i = (i + 2).min(b.len());
                } else if b[i] == b'"' {
                    i += 1;
                    break;
                } else {
                    if b[i] == b'\n' {
                        code[i] = b'\n';
                        line += 1;
                    }
                    i += 1;
                }
            }
            let content_end = if b.get(i.wrapping_sub(1)) == Some(&b'"') { i - 1 } else { i };
            strings.push((start_line, src[start + 1..content_end.max(start + 1)].to_string()));
        } else if c == b'\'' {
            if let Some(end) = char_literal_end(b, i) {
                i = end;
            } else {
                // A lifetime: keep the quote so `'a` stays visible code.
                code[i] = c;
                i += 1;
            }
        } else {
            code[i] = c;
            i += 1;
        }
    }
    Masked {
        code: String::from_utf8_lossy(&code).into_owned(),
        comments,
        strings,
    }
}

/// If `b[i..]` opens a raw string (`r"`, `r#"`, `br##"`, …), return its
/// total byte length including the closing quote/hashes.
fn raw_string_len(b: &[u8], i: usize) -> Option<usize> {
    if i > 0 && is_ident_byte(b[i - 1]) {
        return None;
    }
    let mut j = i;
    if b.get(j) == Some(&b'b') {
        j += 1;
    }
    if b.get(j) != Some(&b'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0usize;
    while b.get(j) == Some(&b'#') {
        hashes += 1;
        j += 1;
    }
    if b.get(j) != Some(&b'"') {
        return None;
    }
    j += 1;
    while j < b.len() {
        if b[j] == b'"' {
            let mut k = j + 1;
            let mut h = 0usize;
            while h < hashes && b.get(k) == Some(&b'#') {
                h += 1;
                k += 1;
            }
            if h == hashes {
                return Some(k - i);
            }
        }
        j += 1;
    }
    Some(b.len() - i)
}

/// If `b[i..]` is a char literal (`'x'`, `'\n'`, `'\''`), return the byte
/// offset one past its closing quote; `None` for a lifetime.
fn char_literal_end(b: &[u8], i: usize) -> Option<usize> {
    debug_assert_eq!(b[i], b'\'');
    if b.get(i + 1) == Some(&b'\\') {
        let mut j = i + 2;
        while j < b.len() && j < i + 12 {
            if b[j] == b'\'' {
                return Some(j + 1);
            }
            j += 1;
        }
        return None;
    }
    // A plain (possibly multi-byte) char closes within a few bytes with
    // no whitespace; a lifetime never has a closing quote.
    let mut j = i + 1;
    while j < b.len() && j <= i + 5 {
        if b[j] == b'\'' {
            return if j == i + 1 { None } else { Some(j + 1) };
        }
        if b[j].is_ascii_whitespace() {
            return None;
        }
        j += 1;
    }
    None
}

fn is_ident_byte(c: u8) -> bool {
    c == b'_' || c.is_ascii_alphanumeric()
}

// ---------------------------------------------------------------------------
// #[cfg(test)] regions
// ---------------------------------------------------------------------------

/// Per-line flags: true where the line lies inside a `#[cfg(test)]`
/// item (attribute through the matching close brace of the item body).
fn test_region_lines(code: &str) -> Vec<bool> {
    let lines = code.lines().count() + 1;
    let mut flags = vec![false; lines];
    let line_of = line_index(code);
    let b = code.as_bytes();
    for (pos, _) in code.match_indices("#[cfg(test)]") {
        let start_line = line_of(pos);
        // The attribute covers the next item: scan to its opening brace
        // (or a `;` for a brace-less declaration).
        let mut j = pos + "#[cfg(test)]".len();
        while j < b.len() && b[j] != b'{' && b[j] != b';' {
            j += 1;
        }
        let end_line = if j < b.len() && b[j] == b'{' {
            line_of(matching_brace(b, j).unwrap_or(b.len() - 1))
        } else {
            line_of(j.min(b.len() - 1))
        };
        for f in flags.iter_mut().take(end_line + 1).skip(start_line) {
            *f = true;
        }
    }
    flags
}

/// Byte offset of the `}` matching the `{` at `open` (in masked code).
fn matching_brace(b: &[u8], open: usize) -> Option<usize> {
    let mut depth = 0i64;
    for (off, &c) in b.iter().enumerate().skip(open) {
        match c {
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                if depth == 0 {
                    return Some(off);
                }
            }
            _ => {}
        }
    }
    None
}

/// A byte-offset → 0-based-line lookup over `text`.
fn line_index(text: &str) -> impl Fn(usize) -> usize {
    let starts: Vec<usize> = std::iter::once(0)
        .chain(text.match_indices('\n').map(|(i, _)| i + 1))
        .collect();
    move |pos: usize| match starts.binary_search(&pos) {
        Ok(l) => l,
        Err(l) => l - 1,
    }
}

// ---------------------------------------------------------------------------
// Site rules: no-unwrap / no-panic / no-lock-unwrap
// ---------------------------------------------------------------------------

/// Modules exempt from the site rules: test utilities and data
/// generators panic by design (they feed tests and benches, not serving).
fn site_exempt(rel: &Path) -> bool {
    let mut comps = rel.components().map(|c| c.as_os_str().to_string_lossy());
    comps.any(|c| c == "testing" || c == "datagen")
}

fn site_rules(sf: &SourceFile) -> Vec<Finding> {
    let mut out = Vec::new();
    if site_exempt(&sf.rel) {
        return out;
    }
    let code = sf.masked.code.as_bytes();
    let line_of = line_index(&sf.masked.code);
    let mut report = |rule: Rule, pos: usize, what: &str| {
        let line = line_of(pos);
        if sf.in_test.get(line).copied().unwrap_or(false) {
            return;
        }
        match allow_status(&sf.masked.comments, line, rule) {
            Allow::Granted => {}
            Allow::None => out.push(Finding {
                rule,
                file: sf.rel.clone(),
                line: line + 1,
                msg: format!("{what} outside test scope (allow with `// lint: allow({}) -- <reason>`)", rule.name()),
            }),
            Allow::MissingReason => out.push(Finding {
                rule,
                file: sf.rel.clone(),
                line: line + 1,
                msg: "allow comment must carry `-- <reason>`".into(),
            }),
        }
    };
    let mut i = 0usize;
    while i < code.len() {
        if code[i] != b'.' && code[i] != b'p' && code[i] != b'u' && code[i] != b't' {
            i += 1;
            continue;
        }
        if code[i] == b'.' {
            if let Some(end) = match_seq(code, i, &[".", "unwrap", "(", ")"]) {
                if lock_call_precedes(code, i) {
                    report(Rule::NoLockUnwrap, i, "`.lock().unwrap()`");
                } else {
                    report(Rule::NoUnwrap, i, "`.unwrap()`");
                }
                i = end;
                continue;
            }
            if let Some(end) = match_seq(code, i, &[".", "expect", "("]) {
                report(Rule::NoUnwrap, i, "`.expect(..)`");
                i = end;
                continue;
            }
            i += 1;
            continue;
        }
        // Macro invocations that abort the thread.
        let mut matched = false;
        for mac in ["panic", "unreachable", "todo", "unimplemented"] {
            if code[i..].starts_with(mac.as_bytes())
                && code.get(i + mac.len()) == Some(&b'!')
                && (i == 0 || !is_ident_byte(code[i - 1]))
            {
                report(Rule::NoPanic, i, &format!("`{mac}!`"));
                i += mac.len() + 1;
                matched = true;
                break;
            }
        }
        if !matched {
            i += 1;
        }
    }
    out
}

/// Match a token sequence starting at `at`, allowing whitespace between
/// tokens; identifier tokens must end at a word boundary. Returns the
/// offset one past the match.
fn match_seq(b: &[u8], at: usize, parts: &[&str]) -> Option<usize> {
    let mut i = at;
    for (pi, part) in parts.iter().enumerate() {
        if pi > 0 {
            while i < b.len() && b[i].is_ascii_whitespace() {
                i += 1;
            }
        }
        if !b[i..].starts_with(part.as_bytes()) {
            return None;
        }
        i += part.len();
        let ident = part.bytes().all(is_ident_byte);
        if ident && i < b.len() && is_ident_byte(b[i]) {
            return None;
        }
    }
    Some(i)
}

/// Does a `lock ( )` call chain immediately precede the `.` at `dot`?
fn lock_call_precedes(b: &[u8], dot: usize) -> bool {
    let mut i = dot;
    let mut expect = |want: u8| -> bool {
        while i > 0 && b[i - 1].is_ascii_whitespace() {
            i -= 1;
        }
        if i > 0 && b[i - 1] == want {
            i -= 1;
            true
        } else {
            false
        }
    };
    if !expect(b')') || !expect(b'(') {
        return false;
    }
    while i > 0 && b[i - 1].is_ascii_whitespace() {
        i -= 1;
    }
    i >= 4 && &b[i - 4..i] == b"lock" && (i == 4 || !is_ident_byte(b[i - 5]))
}

enum Allow {
    None,
    Granted,
    MissingReason,
}

/// Inspect the comments on `line` and `line - 1` for an allow of `rule`.
fn allow_status(comments: &[(usize, String)], line: usize, rule: Rule) -> Allow {
    for (l, text) in comments {
        if *l != line && (*l + 1) != line {
            continue;
        }
        let Some(at) = text.find("lint: allow(") else { continue };
        let rest = &text[at + "lint: allow(".len()..];
        let Some(close) = rest.find(')') else { continue };
        if Rule::from_name(rest[..close].trim()) != Some(rule) {
            continue;
        }
        let tail = &rest[close + 1..];
        let reason_ok = tail
            .split_once("--")
            .is_some_and(|(_, r)| !r.trim().is_empty());
        return if reason_ok { Allow::Granted } else { Allow::MissingReason };
    }
    Allow::None
}

// ---------------------------------------------------------------------------
// Tree rule: error-variants
// ---------------------------------------------------------------------------

fn find_file<'a>(files: &'a [SourceFile], suffix: &str) -> Option<&'a SourceFile> {
    files.iter().find(|f| f.rel.to_string_lossy().ends_with(suffix))
}

fn anchor_missing(rule: Rule, what: &str) -> Vec<Finding> {
    vec![Finding {
        rule,
        file: PathBuf::from("(tree)"),
        line: 0,
        msg: format!("anchor {what} not found — rule cannot hold"),
    }]
}

/// Every `OsebaError` variant must be constructed somewhere: a variant
/// nothing builds is either dead API surface or a forgotten error path.
fn rule_error_variants(files: &[SourceFile]) -> Vec<Finding> {
    let Some(err_file) = find_file(files, "error.rs") else {
        return anchor_missing(Rule::ErrorVariants, "error.rs (enum OsebaError)");
    };
    let Some((span_start, span_end)) = enum_span(&err_file.masked.code, "OsebaError") else {
        return anchor_missing(Rule::ErrorVariants, "enum OsebaError");
    };
    let variants = enum_variants(&err_file.masked.code[span_start..span_end]);
    if variants.is_empty() {
        return anchor_missing(Rule::ErrorVariants, "variants of enum OsebaError");
    }
    let mut out = Vec::new();
    for v in variants {
        let needle = format!("OsebaError::{v}");
        let mut constructed = false;
        'files: for sf in files {
            let line_of = line_index(&sf.masked.code);
            for (pos, _) in sf.masked.code.match_indices(&needle) {
                let end = pos + needle.len();
                if sf.masked.code.as_bytes().get(end).copied().is_some_and(is_ident_byte) {
                    continue; // longer identifier
                }
                // Skip the declaration span itself and match-arm patterns
                // (`OsebaError::X(..) => ...`) — Display/Debug arms are
                // uses, not constructions.
                if std::ptr::eq(sf, err_file) && pos >= span_start && pos < span_end {
                    continue;
                }
                let line = line_of(pos);
                let line_text = sf.masked.code.lines().nth(line).unwrap_or("");
                if line_text.contains("=>") {
                    continue;
                }
                constructed = true;
                break 'files;
            }
        }
        if !constructed {
            out.push(Finding {
                rule: Rule::ErrorVariants,
                file: err_file.rel.clone(),
                line: 0,
                msg: format!("OsebaError::{v} is never constructed"),
            });
        }
    }
    out
}

/// Byte span (start-of-`enum`, one-past-`}`) of `enum <name>` in masked code.
fn enum_span(code: &str, name: &str) -> Option<(usize, usize)> {
    let pat = format!("enum {name}");
    let pos = code.find(&pat)?;
    let b = code.as_bytes();
    let mut open = pos + pat.len();
    while open < b.len() && b[open] != b'{' {
        open += 1;
    }
    let close = matching_brace(b, open)?;
    Some((pos, close + 1))
}

/// Variant names inside an enum body: identifiers at brace depth 1 that
/// start a variant (skipping fields inside `{..}` / `(..)` payloads).
fn enum_variants(span: &str) -> Vec<String> {
    let b = span.as_bytes();
    let mut depth = 0i64;
    let mut out = Vec::new();
    let mut i = 0usize;
    let mut at_variant_start = false;
    while i < b.len() {
        match b[i] {
            b'{' => {
                depth += 1;
                if depth == 1 {
                    at_variant_start = true;
                }
                i += 1;
            }
            b'}' => {
                depth -= 1;
                i += 1;
            }
            b'(' | b'[' => {
                depth += 1;
                i += 1;
            }
            b')' | b']' => {
                depth -= 1;
                i += 1;
            }
            b',' => {
                if depth == 1 {
                    at_variant_start = true;
                }
                i += 1;
            }
            c if depth == 1 && at_variant_start && c.is_ascii_uppercase() => {
                let start = i;
                while i < b.len() && is_ident_byte(b[i]) {
                    i += 1;
                }
                out.push(span[start..i].to_string());
                at_variant_start = false;
            }
            _ => {
                i += 1;
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Tree rule: counters-surfaced
// ---------------------------------------------------------------------------

/// Every `EngineCounters` / `LiveCounters` field must be updated and read
/// somewhere in the crate AND surfaced by the server (its name appears as
/// a response key in non-test `server/mod.rs`). A counter the server
/// never reports is invisible telemetry; one nothing updates is a lie.
/// Likewise every histogram name registered in the metrics registry
/// (`OP_METRICS` / `PHASE_METRICS`) must appear in the server's `metrics`
/// op output.
fn rule_counters_surfaced(files: &[SourceFile]) -> Vec<Finding> {
    let mut out = Vec::new();
    let Some(server) = find_file(files, "server/mod.rs") else {
        return anchor_missing(Rule::CountersSurfaced, "server/mod.rs");
    };
    let surfaced: Vec<&str> = server
        .masked
        .strings
        .iter()
        .filter(|(l, _)| !server.in_test.get(*l).copied().unwrap_or(false))
        .map(|(_, s)| s.as_str())
        .collect();
    for (strukt, anchor) in [
        ("EngineCounters", "engine/context.rs"),
        ("LiveCounters", "engine/live.rs"),
    ] {
        let Some(sf) = find_file(files, anchor) else {
            out.extend(anchor_missing(Rule::CountersSurfaced, anchor));
            continue;
        };
        let Some((span_start, span_end)) = struct_span(&sf.masked.code, strukt) else {
            out.extend(anchor_missing(
                Rule::CountersSurfaced,
                &format!("struct {strukt} in {anchor}"),
            ));
            continue;
        };
        for field in struct_fields(&sf.masked.code[span_start..span_end]) {
            let uses: usize = files
                .iter()
                .map(|f| {
                    word_occurrences(&f.masked.code, &field)
                        .into_iter()
                        .filter(|&pos| {
                            !(std::ptr::eq(f, sf) && pos >= span_start && pos < span_end)
                        })
                        .count()
                })
                .sum();
            if uses < 2 {
                out.push(Finding {
                    rule: Rule::CountersSurfaced,
                    file: sf.rel.clone(),
                    line: 0,
                    msg: format!("{strukt}::{field} is declared but nothing updates and reads it"),
                });
            }
            if !surfaced.iter().any(|s| *s == field) {
                out.push(Finding {
                    rule: Rule::CountersSurfaced,
                    file: sf.rel.clone(),
                    line: 0,
                    msg: format!("{strukt}::{field} is never surfaced as a server response key"),
                });
            }
        }
    }
    // Histogram names get the same treatment: every name registered in
    // the metrics registry's `OP_METRICS` / `PHASE_METRICS` tables must
    // be listed literally by the server's `metrics` op, else it is an
    // invisible histogram nothing can scrape.
    let Some(reg) = find_file(files, "metrics/registry.rs") else {
        out.extend(anchor_missing(Rule::CountersSurfaced, "metrics/registry.rs"));
        return out;
    };
    for const_name in ["OP_METRICS", "PHASE_METRICS"] {
        let Some((start, end)) = const_span(&reg.masked.code, const_name) else {
            out.extend(anchor_missing(
                Rule::CountersSurfaced,
                &format!("const {const_name} in metrics/registry.rs"),
            ));
            continue;
        };
        let (first, last) = (line_at(&reg.masked.code, start), line_at(&reg.masked.code, end));
        for (line, name) in reg.masked.strings.iter().filter(|(l, _)| *l >= first && *l <= last) {
            if !surfaced.iter().any(|s| *s == name.as_str()) {
                out.push(Finding {
                    rule: Rule::CountersSurfaced,
                    file: reg.rel.clone(),
                    line: line + 1,
                    msg: format!(
                        "registered metric \"{name}\" is never surfaced by the server metrics op"
                    ),
                });
            }
        }
    }
    out
}

/// Byte span of `const NAME ... ;` (the `;` at bracket depth 0, so the
/// `;` inside an array-length annotation does not end the span).
fn const_span(code: &str, name: &str) -> Option<(usize, usize)> {
    let pat = format!("const {name}");
    let pos = code.find(&pat)?;
    let b = code.as_bytes();
    let mut depth = 0i64;
    let mut i = pos + pat.len();
    while i < b.len() {
        match b[i] {
            b'[' | b'(' | b'{' => depth += 1,
            b']' | b')' | b'}' => depth -= 1,
            b';' if depth == 0 => return Some((pos, i)),
            _ => {}
        }
        i += 1;
    }
    None
}

/// 0-based line of a byte offset (the convention `mask_source` uses).
fn line_at(code: &str, pos: usize) -> usize {
    code[..pos].bytes().filter(|&b| b == b'\n').count()
}

fn struct_span(code: &str, name: &str) -> Option<(usize, usize)> {
    let pat = format!("struct {name}");
    let pos = code.find(&pat)?;
    let b = code.as_bytes();
    let mut open = pos + pat.len();
    while open < b.len() && b[open] != b'{' && b[open] != b';' {
        open += 1;
    }
    if open >= b.len() || b[open] == b';' {
        return None;
    }
    let close = matching_brace(b, open)?;
    Some((pos, close + 1))
}

/// Field names of a struct body: `ident :` pairs at brace depth 1.
fn struct_fields(span: &str) -> Vec<String> {
    let b = span.as_bytes();
    let mut depth = 0i64;
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < b.len() {
        match b[i] {
            b'{' | b'(' | b'[' | b'<' => {
                depth += 1;
                i += 1;
            }
            b'}' | b')' | b']' | b'>' => {
                depth -= 1;
                i += 1;
            }
            c if depth == 1 && (c == b'_' || c.is_ascii_lowercase()) => {
                let start = i;
                while i < b.len() && is_ident_byte(b[i]) {
                    i += 1;
                }
                let word = &span[start..i];
                let mut j = i;
                while j < b.len() && b[j].is_ascii_whitespace() {
                    j += 1;
                }
                if b.get(j) == Some(&b':') && word != "pub" && word != "crate" {
                    out.push(word.to_string());
                    // Skip the type up to the field-separating comma.
                    let mut d = 0i64;
                    while j < b.len() {
                        match b[j] {
                            b'<' | b'(' | b'[' | b'{' => d += 1,
                            b'>' | b')' | b']' | b'}' => d -= 1,
                            b',' if d == 0 => break,
                            _ => {}
                        }
                        j += 1;
                    }
                    i = j;
                }
            }
            _ => {
                i += 1;
            }
        }
    }
    out
}

/// Byte offsets of word-bounded occurrences of `word` in `text`.
fn word_occurrences(text: &str, word: &str) -> Vec<usize> {
    let b = text.as_bytes();
    text.match_indices(word)
        .filter(|(pos, _)| {
            let before_ok = *pos == 0 || !is_ident_byte(b[pos - 1]);
            let after = pos + word.len();
            let after_ok = after >= b.len() || !is_ident_byte(b[after]);
            before_ok && after_ok
        })
        .map(|(pos, _)| pos)
        .collect()
}

// ---------------------------------------------------------------------------
// Tree rule: manifest-versions
// ---------------------------------------------------------------------------

/// The store manifest's version window (`MIN_VERSION ..= VERSION`) must be
/// handled on both sides: the writer stamps `VERSION`, and the reader
/// carries an explicit upgrade guard (`version < v`) for every format
/// change inside the window, plus the window bounds themselves.
fn rule_manifest_versions(files: &[SourceFile]) -> Vec<Finding> {
    let Some(sf) = find_file(files, "store/manifest.rs") else {
        return anchor_missing(Rule::ManifestVersions, "store/manifest.rs");
    };
    let code = &sf.masked.code;
    let (Some(version), Some(min_version)) =
        (const_value(code, "VERSION"), const_value(code, "MIN_VERSION"))
    else {
        return anchor_missing(Rule::ManifestVersions, "VERSION/MIN_VERSION consts");
    };
    let mut out = Vec::new();
    let mut check_fn = |name: &str, f: &mut dyn FnMut(&str, &mut Vec<Finding>)| {
        match fn_span(code, name) {
            Some((s, e)) => f(&code[s..e], &mut out),
            None => out.extend(anchor_missing(
                Rule::ManifestVersions,
                &format!("fn {name} in store/manifest.rs"),
            )),
        }
    };
    check_fn("to_json", &mut |span, out| {
        if word_occurrences(span, "VERSION").is_empty() {
            out.push(Finding {
                rule: Rule::ManifestVersions,
                file: sf.rel.clone(),
                line: 0,
                msg: "writer to_json does not stamp VERSION".into(),
            });
        }
    });
    check_fn("from_json", &mut |span, out| {
        let squeezed: String = span.chars().filter(|c| !c.is_whitespace()).collect();
        for name in ["MIN_VERSION", "VERSION"] {
            if word_occurrences(span, name).is_empty() {
                out.push(Finding {
                    rule: Rule::ManifestVersions,
                    file: sf.rel.clone(),
                    line: 0,
                    msg: format!("reader from_json does not bound-check {name}"),
                });
            }
        }
        for v in (min_version + 1)..=version {
            if !squeezed.contains(&format!("version<{v}")) {
                out.push(Finding {
                    rule: Rule::ManifestVersions,
                    file: sf.rel.clone(),
                    line: 0,
                    msg: format!(
                        "reader from_json has no `version < {v}` upgrade guard for format v{v}"
                    ),
                });
            }
        }
    });
    out
}

/// The integer value of `const <name>` in masked code.
fn const_value(code: &str, name: &str) -> Option<u64> {
    let pat = format!("const {name}");
    let pos = code.find(&pat)?;
    let rest = &code[pos + pat.len()..];
    let eq = rest.find('=')?;
    let tail = rest[eq + 1..].trim_start();
    let digits: String = tail.chars().take_while(|c| c.is_ascii_digit()).collect();
    digits.parse().ok()
}

/// Byte span of `fn <name>`'s body (brace-matched) in masked code.
fn fn_span(code: &str, name: &str) -> Option<(usize, usize)> {
    let pat = format!("fn {name}");
    for (pos, _) in code.match_indices(&pat) {
        let after = pos + pat.len();
        if code.as_bytes().get(after).copied().is_some_and(is_ident_byte) {
            continue;
        }
        let b = code.as_bytes();
        let mut open = after;
        let mut depth = 0i64;
        // Find the body's `{` (skipping generic/arg brackets).
        while open < b.len() {
            match b[open] {
                b'(' | b'<' | b'[' => depth += 1,
                b')' | b'>' | b']' => depth -= 1,
                b'{' if depth <= 0 => break,
                b';' if depth <= 0 => break,
                _ => {}
            }
            open += 1;
        }
        if open >= b.len() || b[open] != b'{' {
            continue;
        }
        let close = matching_brace(b, open)?;
        return Some((pos, close + 1));
    }
    None
}

// ---------------------------------------------------------------------------
// Site rule: store-io-wrapped
// ---------------------------------------------------------------------------

/// Every filesystem touch in `store/` must go through the failpoint-
/// instrumented [`StoreIo`] wrapper in `store/fault.rs` — a raw
/// `std::fs` call is a write point the crash battery cannot reach and a
/// read the fault storm cannot perturb. Test regions are exempt (they
/// corrupt files *on purpose*, outside the store's own I/O), as is
/// `fault.rs` itself, which owns the real calls.
fn rule_store_io(sf: &SourceFile) -> Vec<Finding> {
    let mut out = Vec::new();
    if !sf.rel.starts_with("store") || sf.rel.file_name().is_some_and(|f| f == "fault.rs") {
        return out;
    }
    let code = &sf.masked.code;
    let b = code.as_bytes();
    let line_of = line_index(code);
    let mut flagged_lines = Vec::new();
    for needle in ["std::fs::", "File::open", "File::create", "OpenOptions"] {
        for (pos, _) in code.match_indices(needle) {
            // Word boundary on the left so `SegmentFile::open` or a
            // hypothetical `MyOpenOptions` cannot trip the rule; `::`
            // on the left means a longer path already matched.
            if pos > 0 && (is_ident_byte(b[pos - 1]) || b[pos - 1] == b':') {
                continue;
            }
            let line = line_of(pos);
            if sf.in_test.get(line).copied().unwrap_or(false) || flagged_lines.contains(&line) {
                continue;
            }
            match allow_status(&sf.masked.comments, line, Rule::StoreIoWrapped) {
                Allow::Granted => {}
                Allow::None => {
                    flagged_lines.push(line);
                    out.push(Finding {
                        rule: Rule::StoreIoWrapped,
                        file: sf.rel.clone(),
                        line: line + 1,
                        msg: format!(
                            "raw `{needle}` bypasses the StoreIo failpoint wrapper \
                             (route through `store/fault.rs`, or allow with \
                             `// lint: allow(store-io-wrapped) -- <reason>`)"
                        ),
                    });
                }
                Allow::MissingReason => {
                    flagged_lines.push(line);
                    out.push(Finding {
                        rule: Rule::StoreIoWrapped,
                        file: sf.rel.clone(),
                        line: line + 1,
                        msg: "allow comment must carry `-- <reason>`".into(),
                    });
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Tree rule: bench-json
// ---------------------------------------------------------------------------

/// Every bench target must emit its machine-readable `BENCH_*.json`
/// artifact via `write_bench_json` — a silent bench falls out of the
/// perf trajectory without anyone noticing.
fn rule_bench_json(bench_root: &Path) -> Result<Vec<Finding>, String> {
    let mut out = Vec::new();
    let entries = match std::fs::read_dir(bench_root) {
        Ok(e) => e,
        Err(_) => return Ok(out), // fixture trees may have no benches
    };
    let mut paths: Vec<PathBuf> = Vec::new();
    for entry in entries {
        let entry = entry.map_err(|e| format!("walk {}: {e}", bench_root.display()))?;
        let path = entry.path();
        if path.is_file() && path.extension().is_some_and(|e| e == "rs") {
            paths.push(path);
        }
    }
    paths.sort();
    for path in paths {
        let raw = std::fs::read_to_string(&path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let masked = mask_source(&raw);
        if word_occurrences(&masked.code, "write_bench_json").is_empty() {
            out.push(Finding {
                rule: Rule::BenchJson,
                file: path,
                line: 0,
                msg: "bench target never calls write_bench_json (no BENCH_*.json artifact)"
                    .into(),
            });
        }
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Tests: scanner primitives + every rule against its seeded fixture
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture(rule: &str) -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures").join(rule)
    }

    fn fired(rule: Rule) -> bool {
        lint_tree(&fixture(rule.name()))
            .expect("fixture lints")
            .iter()
            .any(|f| f.rule == rule)
    }

    #[test]
    fn masking_strips_comments_and_strings() {
        let m = mask_source("let a = \"x.unwrap()\"; // .unwrap()\nb.unwrap();\n");
        assert!(!m.code.contains("x.unwrap"));
        assert!(m.code.contains("b.unwrap()"));
        assert_eq!(m.comments.len(), 1);
        assert_eq!(m.strings[0].1, "x.unwrap()");
    }

    #[test]
    fn masking_handles_char_literals_and_lifetimes() {
        let m = mask_source("fn f<'a>(x: &'a str) -> char { let c = '}'; c }\n");
        // The brace inside the char literal must not unbalance the scan.
        assert_eq!(matching_brace(m.code.as_bytes(), m.code.find('{').unwrap()), Some(m.code.rfind('}').unwrap()));
        assert!(m.code.contains("<'a>"));
    }

    #[test]
    fn masking_handles_raw_strings() {
        let m = mask_source("let s = r#\"panic!(\"x\")\"#; s.len();\n");
        assert!(!m.code.contains("panic!"));
        assert!(m.code.contains("s.len()"));
    }

    #[test]
    fn test_regions_cover_cfg_test_mod() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n  fn b() {}\n}\nfn c() {}\n";
        let flags = test_region_lines(&mask_source(src).code);
        assert!(!flags[0] && flags[1] && flags[2] && flags[3] && flags[4] && !flags[5]);
    }

    #[test]
    fn site_scan_distinguishes_lock_unwrap() {
        let src = "fn f() { m.lock().unwrap(); v.unwrap(); w.expect(\"x\"); }\n";
        let sf = SourceFile {
            rel: PathBuf::from("x.rs"),
            raw: src.into(),
            masked: mask_source(src),
            in_test: vec![false; 3],
        };
        let f = site_rules(&sf);
        assert_eq!(f.iter().filter(|f| f.rule == Rule::NoLockUnwrap).count(), 1);
        assert_eq!(f.iter().filter(|f| f.rule == Rule::NoUnwrap).count(), 2);
    }

    #[test]
    fn unwrap_or_else_is_not_unwrap() {
        let src = "fn f() { m.lock().unwrap_or_else(|e| e.into_inner()); }\n";
        let sf = SourceFile {
            rel: PathBuf::from("x.rs"),
            raw: src.into(),
            masked: mask_source(src),
            in_test: vec![false; 2],
        };
        assert!(site_rules(&sf).is_empty());
    }

    #[test]
    fn allow_comment_needs_reason() {
        let with = "fn f() {\n    // lint: allow(no-unwrap) -- infallible by construction\n    v.unwrap();\n}\n";
        let without = "fn f() {\n    // lint: allow(no-unwrap)\n    v.unwrap();\n}\n";
        let mk = |src: &str| SourceFile {
            rel: PathBuf::from("x.rs"),
            raw: src.into(),
            masked: mask_source(src),
            in_test: vec![false; 5],
        };
        assert!(site_rules(&mk(with)).is_empty());
        let f = site_rules(&mk(without));
        assert_eq!(f.len(), 1);
        assert!(f[0].msg.contains("reason"));
    }

    #[test]
    fn enum_and_struct_parsing() {
        let vs = enum_variants("{ A(String), B { x: usize, y: usize }, CLong, }");
        assert_eq!(vs, ["A", "B", "CLong"]);
        let fs = struct_fields("{ pub a: AtomicUsize, b: Vec<(usize, u64)>, }");
        assert_eq!(fs, ["a", "b"]);
    }

    #[test]
    fn store_io_rule_scopes_to_store_and_respects_boundaries() {
        let src = "fn f() { let _ = std::fs::read(\"x\"); }\n\
                   fn g() { SegmentFile::open(1); }\n\
                   // lint: allow(store-io-wrapped) -- recovery scan needs dirfd\n\
                   fn h() { let _ = std::fs::read_dir(\".\"); }\n";
        let mk = |rel: &str| SourceFile {
            rel: PathBuf::from(rel),
            raw: src.into(),
            masked: mask_source(src),
            in_test: vec![false; 6],
        };
        // In store/: the raw call fires once; the qualified non-`std::fs`
        // call and the justified allow do not.
        let f = rule_store_io(&mk("store/tiered.rs"));
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].line, 1);
        // fault.rs owns the real calls; other modules are out of scope.
        assert!(rule_store_io(&mk("store/fault.rs")).is_empty());
        assert!(rule_store_io(&mk("engine/context.rs")).is_empty());
    }

    #[test]
    fn every_fixture_fires_its_rule() {
        for rule in Rule::ALL {
            assert!(fired(*rule), "fixture for {} must fire", rule.name());
        }
    }

    #[test]
    fn repo_tree_is_clean() {
        // The lint's own acceptance bar: the real tree has zero findings.
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../..");
        let findings = lint_tree(&root).expect("lint repo tree");
        assert!(
            findings.is_empty(),
            "repo tree has lint findings:\n{}",
            findings.iter().map(|f| format!("  {f}\n")).collect::<String>()
        );
    }
}
