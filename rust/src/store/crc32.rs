//! Hand-rolled CRC-32 (IEEE 802.3 polynomial, the zlib/gzip variant) used
//! to checksum every section of an `.oseg` segment file. No external
//! dependency: the 256-entry table is computed once at startup.

use std::sync::OnceLock;

const POLY: u32 = 0xEDB8_8320;

fn table() -> &'static [u32; 256] {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        let mut i = 0usize;
        while i < 256 {
            let mut c = i as u32;
            let mut k = 0;
            while k < 8 {
                c = if c & 1 != 0 { POLY ^ (c >> 1) } else { c >> 1 };
                k += 1;
            }
            t[i] = c;
            i += 1;
        }
        t
    })
}

/// Incremental CRC-32 hasher.
#[derive(Clone, Debug)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Crc32::new()
    }
}

impl Crc32 {
    /// A fresh hasher.
    pub fn new() -> Crc32 {
        Crc32 { state: 0xFFFF_FFFF }
    }

    /// Fold `bytes` into the checksum.
    pub fn update(&mut self, bytes: &[u8]) {
        let t = table();
        for &b in bytes {
            self.state = t[((self.state ^ b as u32) & 0xFF) as usize] ^ (self.state >> 8);
        }
    }

    /// The final checksum value (the hasher may keep updating).
    pub fn finish(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

/// One-shot CRC-32 of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Reference values from the zlib CRC-32.
        assert_eq!(crc32(b""), 0x0000_0000);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn incremental_equals_oneshot() {
        let data = b"hello crc32 world";
        let mut c = Crc32::new();
        c.update(&data[..5]);
        c.update(&data[5..]);
        assert_eq!(c.finish(), crc32(data));
    }

    #[test]
    fn single_bit_flip_changes_checksum() {
        let mut data = vec![0u8; 1024];
        data[100] = 7;
        let a = crc32(&data);
        data[100] ^= 0x10;
        assert_ne!(a, crc32(&data));
    }
}
