//! Small numeric helpers shared by the bench harness and metrics:
//! robust summary statistics over timing samples, and the associative
//! moments algebra used to merge per-partition kernel partials.

/// Summary of a sample of f64 measurements (timings in seconds, bytes, ...).
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    /// Sample size.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Population standard deviation.
    pub std: f64,
    /// Smallest sample.
    pub min: f64,
    /// Median (nearest-rank).
    pub p50: f64,
    /// 95th percentile (nearest-rank).
    pub p95: f64,
    /// 99th percentile (nearest-rank).
    pub p99: f64,
    /// Largest sample.
    pub max: f64,
}

impl Summary {
    /// Compute a summary; returns `None` for an empty sample.
    pub fn of(samples: &[f64]) -> Option<Summary> {
        if samples.is_empty() {
            return None;
        }
        let mut v: Vec<f64> = samples.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = v.len();
        let mean = v.iter().sum::<f64>() / n as f64;
        let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Some(Summary {
            n,
            mean,
            std: var.sqrt(),
            min: v[0],
            p50: percentile(&v, 0.50),
            p95: percentile(&v, 0.95),
            p99: percentile(&v, 0.99),
            max: v[n - 1],
        })
    }
}

/// Nearest-rank percentile of an already-sorted slice.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    debug_assert!(!sorted.is_empty());
    let idx = ((sorted.len() as f64 * q).ceil() as usize).clamp(1, sorted.len());
    sorted[idx - 1]
}

/// Associative raw-moment partial: the merge algebra for `segment_stats`
/// kernel outputs (DESIGN.md §3). `count == 0` is the identity element.
///
/// **NaN policy** (DESIGN.md §10): NaN values are *never* folded into
/// `max`/`min`/`sum`/`sumsq`/`count` — they are counted in `nans` instead,
/// so one corrupt reading cannot silently poison a whole period's mean and
/// standard deviation. `count` is therefore the number of *non-NaN* values.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Moments {
    /// Largest non-NaN value seen (kernel sentinel when empty).
    pub max: f32,
    /// Smallest non-NaN value seen (kernel sentinel when empty).
    pub min: f32,
    /// Sum of non-NaN values.
    pub sum: f64,
    /// Sum of squared non-NaN values.
    pub sumsq: f64,
    /// Number of non-NaN values folded in.
    pub count: f64,
    /// Number of NaN values encountered (excluded from everything above).
    pub nans: f64,
}

impl Moments {
    /// The identity (empty-range) partial, matching the kernel sentinels.
    pub const EMPTY: Moments = Moments {
        max: -3.4e38,
        min: 3.4e38,
        sum: 0.0,
        sumsq: 0.0,
        count: 0.0,
        nans: 0.0,
    };

    /// Build from the five f32 scalars a `segment_stats` execution returns.
    ///
    /// **Caveat:** the AOT kernels report no NaN count, so `nans` is 0
    /// here and a NaN in kernel input still folds into the sums on the
    /// HLO backend. The NaN policy is fully enforced by the native
    /// backend and the predicate-masked engine path (DESIGN.md §10 notes
    /// this as a known kernel-path limitation).
    pub fn from_kernel(max: f32, min: f32, sum: f32, sumsq: f32, count: f32) -> Moments {
        Moments {
            max,
            min,
            sum: sum as f64,
            sumsq: sumsq as f64,
            count: count as f64,
            nans: 0.0,
        }
    }

    /// Single-pass scan of a raw slice (the Native backend / test oracle).
    pub fn scan(xs: &[f32]) -> Moments {
        let mut m = Moments::EMPTY;
        for &x in xs {
            m.absorb(x);
        }
        m
    }

    /// Fold one value in (NaN is counted, not folded).
    pub fn absorb(&mut self, x: f32) {
        if x.is_nan() {
            self.nans += 1.0;
            return;
        }
        self.max = self.max.max(x);
        self.min = self.min.min(x);
        self.sum += x as f64;
        self.sumsq += (x as f64) * (x as f64);
        self.count += 1.0;
    }

    /// Associative merge of two partials.
    pub fn merge(self, other: Moments) -> Moments {
        Moments {
            max: self.max.max(other.max),
            min: self.min.min(other.min),
            sum: self.sum + other.sum,
            sumsq: self.sumsq + other.sumsq,
            count: self.count + other.count,
            nans: self.nans + other.nans,
        }
    }

    /// Whether no value has been folded in.
    pub fn is_empty(&self) -> bool {
        self.count == 0.0
    }

    /// Arithmetic mean (NaN for an empty partial).
    pub fn mean(&self) -> f64 {
        self.sum / self.count
    }

    /// Population standard deviation (matches the paper's "standard
    /// deviation" statistic and `ref.py::finalize_stats`).
    pub fn std(&self) -> f64 {
        let m = self.mean();
        (self.sumsq / self.count - m * m).max(0.0).sqrt()
    }
}

/// Distance partial algebra for the `distance` kernel (l2 kept squared so
/// merging stays associative; take `.l2()` at the very end).
///
/// Same NaN policy as [`Moments`]: a pair whose difference is NaN (either
/// side NaN) is counted in `nans` and excluded from every distance.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DistancePartial {
    /// Sum of absolute differences.
    pub l1: f64,
    /// Sum of squared differences (kept squared so merging is associative).
    pub l2sq: f64,
    /// Largest absolute difference.
    pub linf: f32,
    /// Number of compared (non-NaN) pairs.
    pub count: f64,
    /// Number of pairs excluded because their difference was NaN.
    pub nans: f64,
}

impl DistancePartial {
    /// The identity (empty-range) partial.
    pub const EMPTY: DistancePartial =
        DistancePartial { l1: 0.0, l2sq: 0.0, linf: 0.0, count: 0.0, nans: 0.0 };

    /// Build from the four f32 scalars a `distance` kernel execution returns.
    pub fn from_kernel(l1: f32, l2sq: f32, linf: f32, count: f32) -> Self {
        DistancePartial {
            l1: l1 as f64,
            l2sq: l2sq as f64,
            linf,
            count: count as f64,
            nans: 0.0,
        }
    }

    /// Associative merge of two partials.
    pub fn merge(self, o: DistancePartial) -> DistancePartial {
        DistancePartial {
            l1: self.l1 + o.l1,
            l2sq: self.l2sq + o.l2sq,
            linf: self.linf.max(o.linf),
            count: self.count + o.count,
            nans: self.nans + o.nans,
        }
    }

    /// Finalized Euclidean distance.
    pub fn l2(&self) -> f64 {
        self.l2sq.sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant() {
        let s = Summary::of(&[2.0; 10]).unwrap();
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p50, 2.0);
        assert_eq!(s.min, s.max);
    }

    #[test]
    fn summary_percentiles_ordered() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs).unwrap();
        assert_eq!(s.p50, 50.0);
        assert_eq!(s.p95, 95.0);
        assert_eq!(s.p99, 99.0);
        assert!(s.min <= s.p50 && s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
    }

    #[test]
    fn summary_empty_is_none() {
        assert!(Summary::of(&[]).is_none());
    }

    #[test]
    fn moments_merge_equals_whole_scan() {
        let xs: Vec<f32> = (0..1000).map(|i| (i as f32).sin() * 10.0).collect();
        let whole = Moments::scan(&xs);
        for split in [1, 37, 500, 999] {
            let merged = Moments::scan(&xs[..split]).merge(Moments::scan(&xs[split..]));
            assert!((whole.sum - merged.sum).abs() < 1e-6);
            assert_eq!(whole.max, merged.max);
            assert_eq!(whole.min, merged.min);
            assert_eq!(whole.count, merged.count);
        }
    }

    #[test]
    fn moments_empty_is_identity() {
        let m = Moments::scan(&[1.0, 2.0, 3.0]);
        assert_eq!(m.merge(Moments::EMPTY), m);
        assert_eq!(Moments::EMPTY.merge(m), m);
        assert!(Moments::EMPTY.is_empty());
    }

    #[test]
    fn moments_mean_std_match_numpy_convention() {
        // x = [2, 4, 4, 4, 5, 5, 7, 9] — textbook example: mean 5, pop-std 2.
        let m = Moments::scan(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert_eq!(m.mean(), 5.0);
        assert!((m.std() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn distance_merge_associative() {
        let a = DistancePartial { l1: 1.0, l2sq: 2.0, linf: 0.5, count: 3.0, nans: 1.0 };
        let b = DistancePartial { l1: 2.0, l2sq: 1.0, linf: 0.9, count: 4.0, nans: 0.0 };
        let c = DistancePartial { l1: 0.5, l2sq: 0.25, linf: 1.5, count: 1.0, nans: 2.0 };
        assert_eq!(a.merge(b).merge(c), a.merge(b.merge(c)));
        assert_eq!(a.merge(DistancePartial::EMPTY), a);
    }

    #[test]
    fn distance_l2_is_sqrt() {
        let d = DistancePartial { l1: 0.0, l2sq: 9.0, linf: 0.0, count: 1.0, nans: 0.0 };
        assert_eq!(d.l2(), 3.0);
    }

    #[test]
    fn moments_nan_counted_not_poisoning() {
        // Regression: a single NaN used to poison sum/sumsq (mean and std
        // came out NaN) while count kept growing silently.
        let m = Moments::scan(&[1.0, f32::NAN, 3.0, f32::NAN]);
        assert_eq!(m.count, 2.0);
        assert_eq!(m.nans, 2.0);
        assert_eq!(m.max, 3.0);
        assert_eq!(m.min, 1.0);
        assert_eq!(m.mean(), 2.0);
        assert!(m.std().is_finite());
        // Merging carries the NaN count.
        let merged = m.merge(Moments::scan(&[f32::NAN]));
        assert_eq!(merged.nans, 3.0);
        assert_eq!(merged.count, 2.0);
        // All-NaN scan is the empty partial plus a count.
        let all = Moments::scan(&[f32::NAN; 4]);
        assert!(all.is_empty());
        assert_eq!(all.nans, 4.0);
    }
}
