//! The store manifest: a JSON document (written with the in-tree
//! [`crate::util::json`]) that describes a segment directory — schema,
//! per-segment metadata, and a snapshot of the super index (the CIAS
//! compressed tuple + associated search list) so [`super::TieredStore::open`]
//! restores lookup in O(index size) without reading any segment data.
//!
//! The segment list doubles as the §III-A table index: each entry is
//! exactly one [`PartitionMeta`], so a table-index caller can rebuild from
//! the same manifest.
//!
//! Keys are persisted as JSON numbers; magnitudes beyond 2^53 would lose
//! precision and are rejected at save time.

use std::path::Path;

use crate::error::{OsebaError, Result};
use crate::index::{Cias, PartitionMeta, ZoneMap};
use crate::storage::Schema;
use crate::util::json::Json;

/// Manifest file name inside a store directory.
pub const MANIFEST_FILE: &str = "manifest.json";
/// `format` field value identifying a store manifest.
pub const FORMAT: &str = "oseba-store";
/// Current manifest version. Version 2 added per-segment `zones` (the
/// per-column value-domain zone maps the query planner prunes by). v1
/// manifests are still readable: their zones default to the unbounded
/// sentinel, which never prunes (conservative, correct); `save` rewrites
/// them at v2 with real zones.
pub const VERSION: usize = 2;
/// Oldest manifest version `open` still accepts.
pub const MIN_VERSION: usize = 1;

/// One segment's manifest entry.
#[derive(Clone, Debug, PartialEq)]
pub struct SegmentEntry {
    /// Segment file name, relative to the store directory.
    pub file: String,
    /// The partition metadata (also a table-index row).
    pub meta: PartitionMeta,
    /// Per-column zone maps (one per schema value column), so cold
    /// partitions can be zone-pruned before any fault-in.
    pub zones: Vec<ZoneMap>,
}

/// The parsed/serializable manifest.
#[derive(Clone, Debug)]
pub struct StoreManifest {
    /// Schema of every segment in the store.
    pub schema: Schema,
    /// Per-segment entries, in partition-id order.
    pub segments: Vec<SegmentEntry>,
    /// Super-index snapshot over the segments.
    pub index: Cias,
}

fn meta_to_json(m: &PartitionMeta) -> Json {
    Json::obj(vec![
        ("id", Json::num(m.id as f64)),
        ("key_min", Json::num(m.key_min as f64)),
        ("key_max", Json::num(m.key_max as f64)),
        ("rows", Json::num(m.rows as f64)),
        ("step", m.step.map(|s| Json::num(s as f64)).unwrap_or(Json::Null)),
    ])
}

use crate::store::segment::MAX_ROWS;

fn meta_from_json(v: &Json) -> Result<PartitionMeta> {
    let as_i64 = |name: &str| -> Result<i64> {
        v.require(name)?
            .as_i64()
            .ok_or_else(|| OsebaError::Json(format!("segment field '{name}' must be an integer")))
    };
    let as_usize = |name: &str| -> Result<usize> {
        v.require(name)?.as_usize().ok_or_else(|| {
            OsebaError::Json(format!(
                "segment field '{name}' must be a non-negative integer"
            ))
        })
    };
    let step = match v.require("step")? {
        Json::Null => None,
        j => Some(j.as_i64().ok_or_else(|| {
            OsebaError::Json("segment field 'step' must be an integer or null".into())
        })?),
    };
    let rows = as_usize("rows")?;
    if rows == 0 || rows > MAX_ROWS {
        return Err(OsebaError::Store(format!(
            "segment row count {rows} out of range (1..={MAX_ROWS})"
        )));
    }
    Ok(PartitionMeta {
        id: as_usize("id")?,
        key_min: as_i64("key_min")?,
        key_max: as_i64("key_max")?,
        rows,
        step,
    })
}

fn key_fits(k: i64) -> bool {
    k.unsigned_abs() <= (1u64 << 53)
}

/// JSON rendering of one zone map. JSON has no NaN/Infinity, so an empty
/// zone (no non-NaN value) is written as `{"empty":true,...}` and a
/// non-finite bound degrades to `null` (parsed back as the unbounded
/// sentinel — pruning stays conservative).
fn zone_to_json(z: &ZoneMap) -> Json {
    if z.is_empty() {
        return Json::obj(vec![
            ("empty", Json::Bool(true)),
            ("nans", Json::num(z.nans as f64)),
        ]);
    }
    let bound = |v: f32| {
        if v.is_finite() {
            Json::num(v as f64)
        } else {
            Json::Null
        }
    };
    Json::obj(vec![
        ("min", bound(z.min)),
        ("max", bound(z.max)),
        ("nans", Json::num(z.nans as f64)),
    ])
}

fn zone_from_json(v: &Json) -> Result<ZoneMap> {
    let nans = v.require("nans")?.as_usize().ok_or_else(|| {
        OsebaError::Json("zone field 'nans' must be a non-negative integer".into())
    })?;
    if v.get("empty") == Some(&Json::Bool(true)) {
        return Ok(ZoneMap { nans, ..ZoneMap::EMPTY });
    }
    let bound = |name: &str, unbounded: f32| -> Result<f32> {
        match v.require(name)? {
            Json::Null => Ok(unbounded),
            j => j
                .as_f64()
                .map(|x| x as f32)
                .ok_or_else(|| OsebaError::Json(format!("zone field '{name}' must be a number"))),
        }
    };
    Ok(ZoneMap {
        min: bound("min", f32::NEG_INFINITY)?,
        max: bound("max", f32::INFINITY)?,
        nans,
    })
}

impl StoreManifest {
    /// Serialize. Fails if any key magnitude exceeds JSON-safe 2^53.
    pub fn to_json(&self) -> Result<Json> {
        for e in &self.segments {
            if !key_fits(e.meta.key_min) || !key_fits(e.meta.key_max) {
                return Err(OsebaError::Store(format!(
                    "segment {} keys exceed the manifest's 2^53 range",
                    e.meta.id
                )));
            }
        }
        let (base_key, step, rows_per_part, regular_parts, asl) = self.index.components();
        Ok(Json::obj(vec![
            ("format", Json::str(FORMAT)),
            ("version", Json::num(VERSION as f64)),
            (
                "schema",
                Json::obj(vec![
                    ("key", Json::str(self.schema.key.clone())),
                    (
                        "columns",
                        Json::arr(self.schema.columns.iter().map(|c| Json::str(c.clone())).collect()),
                    ),
                ]),
            ),
            (
                "segments",
                Json::arr(
                    self.segments
                        .iter()
                        .map(|e| {
                            let mut obj = match meta_to_json(&e.meta) {
                                Json::Obj(m) => m,
                                _ => unreachable!(),
                            };
                            obj.insert("file".into(), Json::str(e.file.clone()));
                            obj.insert(
                                "zones".into(),
                                Json::arr(e.zones.iter().map(zone_to_json).collect()),
                            );
                            Json::Obj(obj)
                        })
                        .collect(),
                ),
            ),
            (
                "index",
                Json::obj(vec![
                    ("kind", Json::str("cias")),
                    ("base_key", Json::num(base_key as f64)),
                    ("step", Json::num(step as f64)),
                    ("rows_per_part", Json::num(rows_per_part as f64)),
                    ("regular_parts", Json::num(regular_parts as f64)),
                    ("asl", Json::arr(asl.iter().map(meta_to_json).collect())),
                ]),
            ),
        ]))
    }

    /// Parse and validate a manifest document.
    pub fn from_json(v: &Json) -> Result<StoreManifest> {
        match v.require("format")?.as_str() {
            Some(FORMAT) => {}
            other => {
                return Err(OsebaError::Store(format!(
                    "not a store manifest (format {other:?}, want '{FORMAT}')"
                )))
            }
        }
        let version = match v.require("version")?.as_usize() {
            Some(n) if (MIN_VERSION..=VERSION).contains(&n) => n,
            other => {
                return Err(OsebaError::Store(format!(
                    "unsupported manifest version {other:?} \
                     (want {MIN_VERSION}..={VERSION})"
                )))
            }
        };

        let sv = v.require("schema")?;
        let key = sv
            .require("key")?
            .as_str()
            .ok_or_else(|| OsebaError::Json("schema key must be a string".into()))?;
        let cols = sv
            .require("columns")?
            .as_arr()
            .ok_or_else(|| OsebaError::Json("schema columns must be an array".into()))?;
        let col_names: Vec<&str> = cols
            .iter()
            .map(|c| {
                c.as_str()
                    .ok_or_else(|| OsebaError::Json("schema column must be a string".into()))
            })
            .collect::<Result<_>>()?;
        let schema = Schema::new(key, &col_names)?;

        let segs = v
            .require("segments")?
            .as_arr()
            .ok_or_else(|| OsebaError::Json("segments must be an array".into()))?;
        let mut segments = Vec::with_capacity(segs.len());
        for (i, s) in segs.iter().enumerate() {
            let meta = meta_from_json(s)?;
            if meta.id != i {
                return Err(OsebaError::Store(format!(
                    "segment list out of order: entry {i} has id {}",
                    meta.id
                )));
            }
            let file = s
                .require("file")?
                .as_str()
                .ok_or_else(|| OsebaError::Json("segment file must be a string".into()))?
                .to_string();
            // Segment files must be bare names inside the store directory
            // — a manifest must not be able to point reads elsewhere.
            if file.is_empty()
                || file.contains('/')
                || file.contains('\\')
                || file.starts_with("..")
            {
                return Err(OsebaError::Store(format!(
                    "segment file '{file}' is not a bare file name"
                )));
            }
            // v1 manifests predate zone maps: default every column to the
            // unbounded sentinel — never prunes, always correct.
            let zones = if version < 2 {
                vec![
                    ZoneMap { min: f32::NEG_INFINITY, max: f32::INFINITY, nans: 0 };
                    schema.width()
                ]
            } else {
                let zones = s
                    .require("zones")?
                    .as_arr()
                    .ok_or_else(|| OsebaError::Json("segment zones must be an array".into()))?
                    .iter()
                    .map(zone_from_json)
                    .collect::<Result<Vec<_>>>()?;
                if zones.len() != schema.width() {
                    return Err(OsebaError::Store(format!(
                        "segment {i} has {} zone maps for {} schema columns",
                        zones.len(),
                        schema.width()
                    )));
                }
                zones
            };
            segments.push(SegmentEntry { file, meta, zones });
        }
        if segments.is_empty() {
            return Err(OsebaError::Store("manifest lists no segments".into()));
        }

        let iv = v.require("index")?;
        match iv.require("kind")?.as_str() {
            Some("cias") => {}
            other => {
                return Err(OsebaError::Store(format!("unknown index kind {other:?}")))
            }
        }
        let as_i64 = |name: &str| -> Result<i64> {
            iv.require(name)?
                .as_i64()
                .ok_or_else(|| OsebaError::Json(format!("index field '{name}' must be an integer")))
        };
        let as_usize = |name: &str| -> Result<usize> {
            iv.require(name)?.as_usize().ok_or_else(|| {
                OsebaError::Json(format!(
                    "index field '{name}' must be a non-negative integer"
                ))
            })
        };
        let asl = iv
            .require("asl")?
            .as_arr()
            .ok_or_else(|| OsebaError::Json("index asl must be an array".into()))?
            .iter()
            .map(meta_from_json)
            .collect::<Result<Vec<_>>>()?;
        let index = Cias::from_components(
            as_i64("base_key")?,
            as_i64("step")?,
            as_usize("rows_per_part")?,
            as_usize("regular_parts")?,
            asl,
        )?;
        if index.num_partitions() != segments.len() {
            return Err(OsebaError::Store(format!(
                "index covers {} partitions but manifest lists {} segments",
                index.num_partitions(),
                segments.len()
            )));
        }
        // The segment list is the ground truth (it is what `save` derived
        // the snapshot from); a snapshot that disagrees with it would
        // silently mis-target queries, so reject divergence outright.
        let rebuilt = Cias::from_meta(segments.iter().map(|e| e.meta).collect())?;
        if rebuilt.components() != index.components() {
            return Err(OsebaError::Store(
                "index snapshot disagrees with the segment list".into(),
            ));
        }

        Ok(StoreManifest { schema, segments, index })
    }

    /// Build a manifest for `segments`, deriving the index snapshot.
    pub fn for_segments(schema: Schema, segments: Vec<SegmentEntry>) -> Result<StoreManifest> {
        let index = Cias::from_meta(segments.iter().map(|e| e.meta).collect())?;
        Ok(StoreManifest { schema, segments, index })
    }

    /// Write to `<dir>/manifest.json` atomically (temp file + rename), so
    /// a crash mid-save never clobbers a previously valid manifest.
    pub fn save(&self, dir: impl AsRef<Path>) -> Result<()> {
        let path = dir.as_ref().join(MANIFEST_FILE);
        let tmp = dir.as_ref().join(format!("{MANIFEST_FILE}.tmp"));
        std::fs::write(&tmp, self.to_json()?.to_string())
            .map_err(|e| OsebaError::io(&tmp, e))?;
        std::fs::rename(&tmp, &path).map_err(|e| OsebaError::io(&path, e))
    }

    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<StoreManifest> {
        let path = dir.as_ref().join(MANIFEST_FILE);
        let text =
            std::fs::read_to_string(&path).map_err(|e| OsebaError::io(&path, e))?;
        let v = Json::parse(&text)
            .map_err(|e| OsebaError::Store(format!("manifest '{}': {e}", path.display())))?;
        StoreManifest::from_json(&v)
            .map_err(|e| OsebaError::Store(format!("manifest '{}': {e}", path.display())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::{ContentIndex, RangeQuery};
    use crate::testing::temp_dir;

    fn sample(nparts: usize) -> StoreManifest {
        let rows = 100usize;
        let metas: Vec<PartitionMeta> = (0..nparts)
            .map(|i| PartitionMeta {
                id: i,
                key_min: (i * rows) as i64 * 10,
                key_max: ((i + 1) * rows - 1) as i64 * 10,
                rows,
                step: Some(10),
            })
            .collect();
        let index = Cias::from_meta(metas.clone()).unwrap();
        StoreManifest {
            schema: Schema::stock(),
            segments: metas
                .iter()
                .map(|m| SegmentEntry {
                    file: format!("part-{:05}.oseg", m.id),
                    meta: *m,
                    zones: vec![
                        ZoneMap { min: -1.5, max: 42.0, nans: 0 },
                        ZoneMap { min: 0.0, max: 9.0, nans: 3 },
                    ],
                })
                .collect(),
            index,
        }
    }

    #[test]
    fn roundtrips_through_file() {
        let dir = temp_dir("manifest");
        let m = sample(6);
        m.save(&dir).unwrap();
        let back = StoreManifest::load(&dir).unwrap();
        assert_eq!(back.schema, m.schema);
        assert_eq!(back.segments, m.segments);
        let q = RangeQuery { lo: 150, hi: 3500 };
        assert_eq!(back.index.lookup(q), m.index.lookup(q));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_manifest_names_path() {
        let dir = temp_dir("manifest-miss");
        let err = StoreManifest::load(&dir).unwrap_err();
        assert!(err.to_string().contains("manifest.json"), "got: {err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rejects_tampered_documents() {
        let m = sample(3);
        let good = m.to_json().unwrap().to_string();
        // Wrong format marker.
        let bad = good.replace("oseba-store", "bogus");
        assert!(StoreManifest::from_json(&Json::parse(&bad).unwrap()).is_err());
        // Index/segments disagreement (count).
        let bad = good.replace("\"regular_parts\":3", "\"regular_parts\":2");
        assert!(StoreManifest::from_json(&Json::parse(&bad).unwrap()).is_err());
        // A self-consistent snapshot that diverges from the segment list
        // must also be rejected (it would silently mis-target queries).
        let bad = good.replace("\"base_key\":0", "\"base_key\":10");
        let err = StoreManifest::from_json(&Json::parse(&bad).unwrap()).unwrap_err();
        assert!(err.to_string().contains("disagrees"), "got: {err}");
        // Hostile numerics are clean errors, never panics.
        let bad = good.replace("\"rows\":100", "\"rows\":-1");
        assert!(StoreManifest::from_json(&Json::parse(&bad).unwrap()).is_err());
        let bad = good.replace("\"regular_parts\":3", "\"regular_parts\":-1");
        assert!(StoreManifest::from_json(&Json::parse(&bad).unwrap()).is_err());
        // A segment file must be a bare name — no path escapes.
        let bad = good.replace("part-00001.oseg", "../part-00001.oseg");
        assert!(StoreManifest::from_json(&Json::parse(&bad).unwrap()).is_err());
        // Not JSON at all.
        assert!(Json::parse("not json").is_err());
        // Zone-map count must match the schema width.
        let bad = good.replace(
            r#""zones":[{"#,
            r#""zones":[{"min":0,"max":1,"nans":0},{"#,
        );
        assert!(StoreManifest::from_json(&Json::parse(&bad).unwrap()).is_err());
    }

    #[test]
    fn v1_manifest_still_opens_with_unbounded_zones() {
        // A manifest saved before zone maps existed (version 1, no
        // `zones` field) must stay readable: its zones default to the
        // never-prune sentinel, so old stores are not bricked.
        let good = sample(2).to_json().unwrap().to_string();
        let v1 = good
            .replace("\"version\":2", "\"version\":1")
            .replace(
                r#","zones":[{"max":42,"min":-1.5,"nans":0},{"max":9,"min":0,"nans":3}]"#,
                "",
            );
        assert!(!v1.contains("zones"), "surgery must strip every zones field");
        let m = StoreManifest::from_json(&Json::parse(&v1).unwrap()).unwrap();
        for e in &m.segments {
            assert_eq!(e.zones.len(), 2);
            for z in &e.zones {
                assert_eq!(z.min, f32::NEG_INFINITY);
                assert_eq!(z.max, f32::INFINITY);
                assert_eq!(z.nans, 0);
            }
        }
        // Unknown future versions are still rejected.
        let v9 = good.replace("\"version\":2", "\"version\":9");
        assert!(StoreManifest::from_json(&Json::parse(&v9).unwrap()).is_err());
    }

    #[test]
    fn zone_maps_roundtrip_including_empty() {
        let mut m = sample(2);
        // One all-NaN column (empty bounds) must survive the round trip.
        m.segments[1].zones[0] = ZoneMap { nans: 7, ..ZoneMap::EMPTY };
        let back = StoreManifest::from_json(&m.to_json().unwrap()).unwrap();
        assert_eq!(back.segments[0].zones, m.segments[0].zones);
        let z = &back.segments[1].zones[0];
        assert!(z.is_empty());
        assert_eq!(z.nans, 7);
        assert_eq!(back.segments[1].zones[1], m.segments[1].zones[1]);
    }
}
