//! Table-based content-aware organization (paper §III-A, Fig 3).
//!
//! A sorted table of `(partition id → key range)` entries; lookups binary
//! search the table. Space is O(m) in the number of partitions and lookup
//! is O(log m) — the costs §III-B motivates CIAS against.

use std::sync::Arc;

use crate::error::{OsebaError, Result};
use crate::index::builder::{extract_meta, slice_for_meta};
use crate::index::types::{ContentIndex, PartitionMeta, PartitionSlice, RangeQuery};
use crate::storage::Partition;

/// The intuitive table index of Fig 3.
#[derive(Clone, Debug)]
pub struct TableIndex {
    entries: Vec<PartitionMeta>,
}

impl TableIndex {
    /// Build from loaded partitions. Requires partitions to be
    /// range-ordered and non-overlapping (the engine's load layout).
    pub fn build(parts: &[Arc<Partition>]) -> Result<TableIndex> {
        Self::from_meta(extract_meta(parts))
    }

    /// Build from already-extracted metadata (shared with CIAS tests).
    pub fn from_meta(entries: Vec<PartitionMeta>) -> Result<TableIndex> {
        if entries.is_empty() {
            return Err(OsebaError::Index("empty partition set".into()));
        }
        // Inclusive ranges: a shared boundary key is an overlap (a point
        // query on it would double-count) — mirrors `Cias::from_meta`.
        for w in entries.windows(2) {
            if w[0].key_max >= w[1].key_min {
                return Err(OsebaError::Index(format!(
                    "partitions {} and {} overlap ({} >= {})",
                    w[0].id, w[1].id, w[0].key_max, w[1].key_min
                )));
            }
        }
        Ok(TableIndex { entries })
    }

    /// The table rows (inspection / bench reporting).
    pub fn entries(&self) -> &[PartitionMeta] {
        &self.entries
    }
}

impl ContentIndex for TableIndex {
    fn name(&self) -> &'static str {
        "table"
    }

    fn lookup(&self, q: RangeQuery) -> Vec<PartitionSlice> {
        // Binary search: first partition whose key_max >= lo ...
        let start = self.entries.partition_point(|m| m.key_max < q.lo);
        let mut out = Vec::new();
        // ... then walk right while partitions intersect (output-sensitive).
        for m in &self.entries[start..] {
            if m.key_min > q.hi {
                break;
            }
            if let Some(s) = slice_for_meta(m, q) {
                out.push(s);
            }
        }
        out
    }

    fn memory_bytes(&self) -> usize {
        self.entries.len() * std::mem::size_of::<PartitionMeta>()
    }

    fn num_partitions(&self) -> usize {
        self.entries.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::{partition_batch_uniform, BatchBuilder, Schema};

    fn index(rows: usize, per: usize) -> TableIndex {
        let mut b = BatchBuilder::new(Schema::stock());
        for i in 0..rows {
            b.push(i as i64 * 10, &[i as f32, 0.0]);
        }
        let parts = partition_batch_uniform(&b.finish().unwrap(), per).unwrap();
        TableIndex::build(&parts).unwrap()
    }

    #[test]
    fn lookup_single_partition() {
        let ix = index(100, 25); // keys 0..990 step 10, 4 partitions
        let got = ix.lookup(RangeQuery { lo: 0, hi: 240 });
        assert_eq!(got, vec![PartitionSlice { partition: 0, row_start: 0, row_end: 25 }]);
    }

    #[test]
    fn lookup_spanning_partitions() {
        let ix = index(100, 25);
        let got = ix.lookup(RangeQuery { lo: 200, hi: 600 });
        assert_eq!(got.len(), 3);
        assert_eq!(got[0], PartitionSlice { partition: 0, row_start: 20, row_end: 25 });
        assert_eq!(got[1], PartitionSlice { partition: 1, row_start: 0, row_end: 25 });
        // Partition 2 holds keys 500..740; [200,600] covers 500..600 → rows 0..11.
        assert_eq!(got[2], PartitionSlice { partition: 2, row_start: 0, row_end: 11 });
    }

    #[test]
    fn lookup_miss_is_empty() {
        let ix = index(100, 25);
        assert!(ix.lookup(RangeQuery { lo: 99_999, hi: 100_000 }).is_empty());
        assert!(ix.lookup(RangeQuery { lo: -100, hi: -1 }).is_empty());
    }

    #[test]
    fn lookup_full_span() {
        let ix = index(100, 25);
        let got = ix.lookup(RangeQuery { lo: i64::MIN + 1, hi: i64::MAX });
        assert_eq!(got.len(), 4);
        assert!(got.iter().all(|s| s.rows() == 25));
    }

    #[test]
    fn memory_grows_linearly_with_partitions() {
        let small = index(100, 25).memory_bytes();
        let large = index(1000, 25).memory_bytes();
        assert_eq!(large, 10 * small);
    }

    #[test]
    fn rejects_overlapping_partitions() {
        let metas = vec![
            PartitionMeta { id: 0, key_min: 0, key_max: 100, rows: 10, step: Some(10) },
            PartitionMeta { id: 1, key_min: 50, key_max: 150, rows: 10, step: Some(10) },
        ];
        assert!(TableIndex::from_meta(metas).is_err());
    }

    #[test]
    fn rejects_empty() {
        assert!(TableIndex::from_meta(vec![]).is_err());
    }

    #[test]
    fn rejects_shared_boundary_key() {
        // Regression: a shared boundary key between inclusive ranges is an
        // overlap (a point query on it would double-count rows).
        let metas = vec![
            PartitionMeta { id: 0, key_min: 0, key_max: 100, rows: 10, step: Some(10) },
            PartitionMeta { id: 1, key_min: 100, key_max: 190, rows: 10, step: Some(10) },
        ];
        assert!(TableIndex::from_meta(metas).is_err());
    }
}
