//! The Spark-like in-memory processing substrate: datasets (RDDs) with
//! lineage, a block manager with storage-memory accounting, the two
//! competing selective-access paths (scan-filter vs indexed slices), and
//! live (append-while-serving) datasets with epoch-pinned snapshots.

pub mod block_manager;
pub mod context;
pub mod dataset;
pub mod live;
pub mod memory;

pub use block_manager::{BlockManager, DatasetId};
pub use context::{CounterSnapshot, OsebaContext};
pub use dataset::{Dataset, Lineage, PinnedSlice, PinnedSlices, SliceView};
pub use live::{EpochSnapshot, LiveConfig, LiveCounters, LiveDataset};
pub use memory::MemoryTracker;
