"""Windowed-mean (moving average) Pallas kernel.

Paper §II: "Moving Average is often implemented in the analysis of a time
series to smooth out short-term fluctuations". A ``w``-point trailing MA at
row ``i`` averages ``x[i-w+1 : i+1]``.

The window must be static for AOT lowering, so ``aot.py`` emits one
executable per window in ``MA_WINDOWS``; the rust side picks the nearest
window variant (exact-match only in the public API).

Implementation: the kernel computes a masked prefix-sum formulation —
``cumsum`` shifted by ``w`` — entirely inside one VMEM tile, then masks
positions outside ``[start+w-1, end)`` (rows whose window would cross the
selection's left edge are invalid and set to 0).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 4096


def _ma_kernel(x_ref, start_ref, end_ref, o_ref, *, window):
    x = x_ref[...]
    idx = jax.lax.broadcasted_iota(jnp.int32, x.shape, 0)
    sel = (idx >= start_ref[0]) & (idx < end_ref[0])
    xm = x * sel.astype(jnp.float32)
    c = jnp.cumsum(xm)
    shifted = jnp.concatenate([jnp.zeros((window,), jnp.float32),
                               c[:-window]])
    win_sum = c - shifted
    # Row i is a valid MA point iff its whole window lies inside [start, end).
    valid = (idx >= start_ref[0] + window - 1) & (idx < end_ref[0])
    o_ref[...] = jnp.where(valid, win_sum / jnp.float32(window),
                           jnp.zeros_like(x))


@functools.partial(jax.jit, static_argnames=("window", "block_rows"))
def moving_average(x, start, end, *, window, block_rows=None):
    """Trailing ``window``-point moving average of ``x[start:end]``.

    Returns f32[n] (n = x rows): position ``i`` holds the MA ending at row
    ``i`` when the full window fits inside the selection, else 0.
    """
    assert block_rows is None or x.shape[0] == block_rows
    start = jnp.asarray(start, jnp.int32).reshape((1,))
    end = jnp.asarray(end, jnp.int32).reshape((1,))
    kern = functools.partial(_ma_kernel, window=window)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((x.shape[0],), jnp.float32),
        interpret=True,
    )(x, start, end)
