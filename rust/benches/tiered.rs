//! **Tiered-store bench**: a selective workload over a dataset ~4× the
//! memory budget. The point of the tier: the index decides which segments
//! are faulted in, so a selective analysis reads a small fraction of the
//! dataset from disk, while the scan-everything baseline pays a full
//! reload — and a `save`/`open` round trip restores the super index from
//! the manifest snapshot without rescanning any data.
//!
//! Run: `cargo bench --bench tiered`
//! (OSEBA_TIERED_BUDGET rescales; dataset is 4× the budget.)

mod common;

use std::sync::Arc;

use oseba::bench::{bench, section, table, BenchConfig};
use oseba::config::{parse_bytes, BackendKind, ContextConfig};
use oseba::coordinator::{Coordinator, IndexKind};
use oseba::datagen::ClimateGen;
use oseba::index::RangeQuery;
use oseba::runtime::make_backend;
use oseba::util::humansize;

const PARTITIONS: usize = 32;

fn coordinator(budget: Option<usize>) -> Coordinator {
    let mut cfg = common::app_cfg(BackendKind::Native);
    cfg.ctx = ContextConfig { num_workers: 4, memory_budget: budget };
    let be = make_backend(cfg.backend, &cfg.artifacts_dir).expect("backend");
    Coordinator::new(&cfg, be).expect("coordinator")
}

fn main() {
    let budget = std::env::var("OSEBA_TIERED_BUDGET")
        .ok()
        .map(|v| parse_bytes(&v).expect("OSEBA_TIERED_BUDGET"))
        .unwrap_or(8 << 20);
    let raw = 4 * budget;
    let dir = std::env::temp_dir().join(format!("oseba-tiered-bench-{}", std::process::id()));

    section(&format!(
        "Tiered store: {} dataset under a {} budget ({} partitions)",
        humansize::bytes(raw),
        humansize::bytes(budget),
        PARTITIONS
    ));

    let coord = coordinator(Some(budget));
    let batch = ClimateGen::default().generate_bytes(raw);
    let ds = coord.load_tiered(batch, PARTITIONS, &dir).expect("tiered load");
    let store = Arc::clone(ds.store().expect("tiered"));
    let index = coord.build_index(&ds, IndexKind::Cias).expect("index");
    let total = store.total_bytes();
    assert!(
        store.resident_bytes() <= budget,
        "residency within budget after load"
    );
    println!(
        "loaded: {} total, {} resident, {} spills during ingest",
        humansize::bytes(total),
        humansize::bytes(store.resident_bytes()),
        store.counters().evictions
    );

    // Six disjoint narrow queries spread across the key span (each
    // ~1/256 of the span, well inside one partition) — the selective
    // interactive workload.
    let (kmin, kmax) = (ds.key_min().unwrap(), ds.key_max().unwrap());
    let span = kmax - kmin;
    let width = (span / 256).max(1);
    let queries: Vec<RangeQuery> = (0..6)
        .map(|i| {
            let lo = kmin + span * (2 * i) as i64 / 16;
            RangeQuery { lo, hi: (lo + width).min(kmax) }
        })
        .collect();

    let cfg = BenchConfig { warmup_iters: 1, iters: 5 };
    let mut results = Vec::new();

    let before_sel = store.counters();
    results.push(bench(&cfg, "selective batch (indexed fault-in)", || {
        coord
            .analyze_batch(&ds, index.as_ref(), &queries, 0)
            .expect("selective batch");
    }));
    let sel = store.counters().since(&before_sel);
    let sel_iters = cfg.warmup_iters + cfg.iters;
    let sel_read_per_iter = sel.segment_bytes_read / sel_iters;

    let before_full = store.counters();
    results.push(bench(&cfg, "full reload (scan-everything baseline)", || {
        // The baseline touches every partition: fault the whole dataset.
        let handles = coord.context().partition_handles(&ds).expect("full reload");
        assert_eq!(handles.len(), PARTITIONS);
    }));
    let full = store.counters().since(&before_full);
    let full_read_per_iter = full.segment_bytes_read / sel_iters;

    println!("{}", table(&results));
    println!(
        "bytes read per run: selective {} vs full reload {} (dataset {})",
        humansize::bytes(sel_read_per_iter),
        humansize::bytes(full_read_per_iter),
        humansize::bytes(total)
    );
    println!(
        "selective fraction: {:.1}% of dataset, {} faults / {} evictions per run",
        100.0 * sel_read_per_iter as f64 / total as f64,
        sel.faults / sel_iters,
        sel.evictions / sel_iters
    );

    // The reproduction contract: selectivity must show up as I/O savings.
    assert!(
        sel_read_per_iter < total / 3,
        "selective reads ({sel_read_per_iter}) must be ≪ dataset ({total})"
    );
    assert!(
        sel_read_per_iter < full_read_per_iter / 2,
        "selective ({sel_read_per_iter}) must beat full reload ({full_read_per_iter})"
    );

    // --- save / open round trip -----------------------------------------
    section("save / open round trip");
    let want = coord
        .analyze_batch(&ds, index.as_ref(), &queries, 0)
        .expect("reference stats");
    let t = std::time::Instant::now();
    store.save().expect("save");
    let save_secs = t.elapsed().as_secs_f64();

    let coord2 = coordinator(Some(budget));
    let t = std::time::Instant::now();
    let (ds2, index2) = coord2.open_store(&dir).expect("open");
    let open_secs = t.elapsed().as_secs_f64();
    let store2 = Arc::clone(ds2.store().expect("tiered"));
    assert_eq!(
        store2.counters().segment_bytes_read,
        0,
        "open must not read segment data"
    );
    assert_eq!(ds2.total_rows(), ds.total_rows());

    let got = coord2
        .analyze_batch(&ds2, index2.as_ref(), &queries, 0)
        .expect("post-open batch");
    for (g, w) in got.iter().zip(&want) {
        assert_eq!(g.count, w.count);
        assert_eq!(g.max, w.max);
        assert!((g.mean - w.mean).abs() < 1e-9);
    }
    println!(
        "save {} | open {} (index restored from snapshot, 0 bytes of data read)",
        humansize::secs(save_secs),
        humansize::secs(open_secs)
    );
    println!(
        "post-open selective batch read {} of {}",
        humansize::bytes(store2.counters().segment_bytes_read),
        humansize::bytes(total)
    );
    println!("\nshape check: selective ≪ full ✓, save/open round trip exact ✓");

    use oseba::util::json::Json;
    common::write_bench_json(
        "tiered",
        Json::obj(vec![
            ("bench", Json::str("tiered")),
            ("raw_bytes", Json::num(raw as f64)),
            ("budget_bytes", Json::num(budget as f64)),
            ("dataset_bytes", Json::num(total as f64)),
            ("selective_bytes_read_per_run", Json::num(sel_read_per_iter as f64)),
            ("full_reload_bytes_read_per_run", Json::num(full_read_per_iter as f64)),
            ("selective_faults_per_run", Json::num((sel.faults / sel_iters) as f64)),
            ("save_secs", Json::num(save_secs)),
            ("open_secs", Json::num(open_secs)),
        ]),
    );
    let _ = std::fs::remove_dir_all(&dir);
}
