//! The leader: query planning, task routing/batching over the simulated
//! cluster, partial merging, and the interactive-session driver that
//! produces the paper's Fig 4 / Fig 6 measurements.

pub mod planner;
pub mod session;

pub use planner::{plan_batch, IndexKind, Method, PlannedQuery};
pub use session::{run_batch_session, run_session, BatchSessionReport, SessionReport};

use std::sync::Arc;

use crate::analysis::ops::slice_moments;
use crate::analysis::{Analyzer, PeriodStats};
use crate::cluster::{Cluster, NetworkModel};
use crate::config::AppConfig;
use crate::engine::{Dataset, EpochSnapshot, LiveConfig, LiveDataset, OsebaContext};
use crate::error::{OsebaError, Result};
use crate::index::{Cias, ContentIndex, RangeQuery, TableIndex};
use crate::metrics::{BatchReport, Timer};
use crate::runtime::backend::AnalysisBackend;
use crate::storage::{Partition, RecordBatch, Schema};
use crate::util::stats::Moments;

/// The driver/leader of the system.
pub struct Coordinator {
    ctx: OsebaContext,
    analyzer: Analyzer,
    backend: Arc<dyn AnalysisBackend>,
    cluster: Cluster,
    /// Batch all of a worker's kernel blocks into one backend submission.
    pub batch_kernel_calls: bool,
}

impl Coordinator {
    /// Build from config + an already-constructed backend.
    pub fn new(cfg: &AppConfig, backend: Arc<dyn AnalysisBackend>) -> Result<Coordinator> {
        let ctx = OsebaContext::new(cfg.ctx.clone());
        let cluster = Cluster::new(
            cfg.cluster_workers,
            0,
            NetworkModel { latency_us: cfg.net_latency_us },
        )?;
        Ok(Coordinator {
            ctx,
            analyzer: Analyzer::new(Arc::clone(&backend)),
            backend,
            cluster,
            batch_kernel_calls: true,
        })
    }

    /// The engine context this coordinator drives.
    pub fn context(&self) -> &OsebaContext {
        &self.ctx
    }

    /// The analysis engine (backend + block decomposition).
    pub fn analyzer(&self) -> &Analyzer {
        &self.analyzer
    }

    /// The simulated cluster (placement, liveness, network model).
    pub fn cluster(&self) -> &Cluster {
        &self.cluster
    }

    /// Load a batch as a cached dataset and register its partitions with
    /// the cluster placement.
    pub fn load(&self, batch: RecordBatch, num_partitions: usize) -> Result<Dataset> {
        let ds = self.ctx.load(batch, num_partitions)?;
        self.cluster.ensure_partitions(ds.num_partitions());
        Ok(ds)
    }

    /// Load a batch as a **tiered** dataset rooted at `dir`: partitions
    /// spill to `.oseg` segments under memory pressure instead of failing
    /// the load, so datasets larger than the budget are admissible.
    pub fn load_tiered(
        &self,
        batch: RecordBatch,
        num_partitions: usize,
        dir: impl AsRef<std::path::Path>,
    ) -> Result<Dataset> {
        let ds = self.ctx.load_tiered(batch, num_partitions, dir)?;
        self.cluster.ensure_partitions(ds.num_partitions());
        Ok(ds)
    }

    /// Open a saved store directory as a tiered dataset, restoring the
    /// super index from its manifest snapshot (no segment data is read).
    pub fn open_store(
        &self,
        dir: impl AsRef<std::path::Path>,
    ) -> Result<(Dataset, Box<dyn ContentIndex>)> {
        let (ds, index) = self.ctx.open_tiered(dir)?;
        self.cluster.ensure_partitions(ds.num_partitions());
        Ok((ds, Box::new(index)))
    }

    /// Create a **live** (append-while-serving) dataset on this
    /// coordinator's engine. Writers stream chunks in (directly or via
    /// [`crate::ingest::LiveIngestor`]); queries go through the
    /// snapshot-pinned [`Self::analyze_live`] / [`Self::analyze_live_batch`].
    pub fn create_live(&self, schema: Schema, cfg: LiveConfig) -> Result<Arc<LiveDataset>> {
        self.ctx.create_live(schema, cfg)
    }

    /// [`Self::create_live`] with sealed-partition spill to a
    /// [`crate::store::TieredStore`] rooted at `dir`.
    pub fn create_live_spilling(
        &self,
        schema: Schema,
        cfg: LiveConfig,
        dir: impl AsRef<std::path::Path>,
    ) -> Result<Arc<LiveDataset>> {
        self.ctx.create_live_spilling(schema, cfg, dir)
    }

    /// Pin the live dataset's current epoch and register its partitions
    /// with the cluster placement — every live analysis goes through here
    /// so a plan can never see a half-published partition.
    pub fn snapshot_live(&self, live: &LiveDataset) -> EpochSnapshot {
        let snap = live.snapshot();
        self.cluster.ensure_partitions(snap.num_partitions());
        snap
    }

    /// **Live Oseba phase**: snapshot-pinned single-query analysis.
    /// Returns the stats plus the epoch they were computed at.
    pub fn analyze_live(
        &self,
        live: &LiveDataset,
        q: RangeQuery,
        column: usize,
    ) -> Result<(PeriodStats, u64)> {
        let snap = self.snapshot_live(live);
        let index = snap.index().ok_or_else(|| {
            OsebaError::InvalidRange("live dataset has no sealed partitions yet".into())
        })?;
        let stats = self.analyze_period_oseba(snap.dataset(), index, q, column)?;
        Ok((stats, snap.epoch()))
    }

    /// **Live batch phase**: one epoch snapshot serves the whole planned
    /// batch, so every merged range, segment and demuxed result refers to
    /// the same immutable partition set even while appends continue.
    /// Returns per-query stats, the batch report, and the pinned epoch.
    pub fn analyze_live_batch(
        &self,
        live: &LiveDataset,
        queries: &[RangeQuery],
        column: usize,
    ) -> Result<(Vec<PeriodStats>, BatchReport, u64)> {
        let snap = self.snapshot_live(live);
        let index = snap.index().ok_or_else(|| {
            OsebaError::InvalidRange("live dataset has no sealed partitions yet".into())
        })?;
        let (stats, report) =
            self.analyze_batch_with_report(snap.dataset(), index, queries, column)?;
        Ok((stats, report, snap.epoch()))
    }

    /// Build the configured index over a dataset. For a tiered dataset the
    /// index is built from the store's metadata — no partition is faulted
    /// in.
    pub fn build_index(&self, ds: &Dataset, kind: IndexKind) -> Result<Box<dyn ContentIndex>> {
        if let Some(store) = ds.store() {
            let metas = store.metas();
            return Ok(match kind {
                IndexKind::Table => Box::new(TableIndex::from_meta(metas)?),
                IndexKind::Cias => Box::new(Cias::from_meta(metas)?),
            });
        }
        Ok(match kind {
            IndexKind::Table => Box::new(TableIndex::build(ds.partitions())?),
            IndexKind::Cias => Box::new(Cias::build(ds.partitions())?),
        })
    }

    /// **Baseline phase** (paper §IV-A "first method"): filter-scan all
    /// partitions, materialize + cache the selection, then analyze the
    /// filtered dataset. Returns the stats *and* the filtered dataset
    /// handle — which stays resident, exactly like Spark's default.
    pub fn analyze_period_default(
        &self,
        ds: &Dataset,
        q: RangeQuery,
        column: usize,
    ) -> Result<(PeriodStats, Dataset)> {
        let filtered = self.ctx.filter_range(ds, q)?;
        self.cluster.ensure_partitions(filtered.num_partitions());
        if filtered.total_rows() == 0 {
            return Err(OsebaError::InvalidRange(format!(
                "no rows in [{}, {}]",
                q.lo, q.hi
            )));
        }
        // Analyze every row of the filtered dataset, routed per worker.
        let slices: Vec<_> = filtered
            .partitions()
            .iter()
            .filter(|p| p.rows > 0)
            .map(|p| crate::index::PartitionSlice { partition: p.id, row_start: 0, row_end: p.rows })
            .collect();
        let owned: Vec<_> = slices
            .iter()
            .map(|s| (Arc::clone(&filtered.partitions()[s.partition]), *s))
            .collect();
        let stats = self.run_stats_tasks(owned, column)?;
        Ok((stats, filtered))
    }

    /// **Oseba phase** (paper §IV-A "second method"): index lookup targets
    /// the partitions + row ranges; per-worker tasks compute moments over
    /// zero-copy views of the *original* partitions; the leader merges.
    pub fn analyze_period_oseba(
        &self,
        ds: &Dataset,
        index: &dyn ContentIndex,
        q: RangeQuery,
        column: usize,
    ) -> Result<PeriodStats> {
        let slices = index.lookup(q);
        if slices.is_empty() {
            return Err(OsebaError::InvalidRange(format!(
                "no partitions intersect [{}, {}]",
                q.lo, q.hi
            )));
        }
        let owned = self.ctx.resolve_slices(ds, &slices, q)?;
        self.run_stats_tasks(owned, column)
    }

    /// **Batch phase** (many concurrent sessions, one engine): plan N
    /// possibly-overlapping queries into disjoint merged ranges
    /// ([`plan_batch`]), route each merged range through the cluster
    /// *once*, execute every per-worker task concurrently on the engine
    /// thread pool, and demultiplex exact per-query [`PeriodStats`] from
    /// the shared elementary-segment partials.
    ///
    /// Overlap between input queries costs nothing extra: each partition
    /// intersecting a merged range is resolved (and counted in
    /// [`crate::engine::CounterSnapshot::partitions_targeted`]) exactly
    /// once per merged range, however many queries cover it — so a batch
    /// of N mutually-overlapping queries targets each partition once,
    /// instead of N times.
    ///
    /// Takes `&self` and is safe to call from many threads at once — the
    /// coordinator is `Send + Sync`.
    pub fn analyze_batch(
        &self,
        ds: &Dataset,
        index: &dyn ContentIndex,
        queries: &[RangeQuery],
        column: usize,
    ) -> Result<Vec<PeriodStats>> {
        self.analyze_batch_with_report(ds, index, queries, column).map(|(stats, _)| stats)
    }

    /// [`Self::analyze_batch`] plus the planner/execution counters.
    pub fn analyze_batch_with_report(
        &self,
        ds: &Dataset,
        index: &dyn ContentIndex,
        queries: &[RangeQuery],
        column: usize,
    ) -> Result<(Vec<PeriodStats>, BatchReport)> {
        let timer = Timer::start();
        let store_before =
            ds.store().map(|s| s.counters()).unwrap_or_default();
        for (i, q) in queries.iter().enumerate() {
            if q.lo > q.hi {
                return Err(OsebaError::InvalidRange(format!(
                    "query {i}: lo {} > hi {}",
                    q.lo, q.hi
                )));
            }
        }
        let plan = plan_batch(queries);

        // Global elementary-segment table across all merged ranges: the
        // shared partials per-query stats are demultiplexed from.
        let mut segments: Vec<RangeQuery> = Vec::new();
        let mut seg_sources: Vec<Vec<usize>> = Vec::new();
        // One work list per (merged range, owning worker), executed as one
        // pool task each — independent merged queries run concurrently.
        type SubSlice = (Arc<Partition>, usize, usize, usize);
        let mut worker_lists: Vec<Vec<SubSlice>> = Vec::new();
        let mut partitions_touched = 0usize;

        for pq in &plan {
            let slices = index.lookup(pq.range);
            // One resolve per merged range: N queries overlapping this
            // range cost one `partitions_targeted` count per partition,
            // not N.
            partitions_touched += slices.len();
            let owned = self.ctx.resolve_slices(ds, &slices, pq.range)?;
            let seg_base = segments.len();
            for (seg, srcs) in pq.segments(queries) {
                segments.push(seg);
                seg_sources.push(srcs);
            }
            let mut items: Vec<(usize, SubSlice)> = Vec::new();
            for (part, slice) in &owned {
                for (si, seg) in segments[seg_base..].iter().enumerate() {
                    let rs = part.lower_bound(seg.lo).max(slice.row_start);
                    let re = part.upper_bound(seg.hi).min(slice.row_end);
                    if rs < re {
                        items.push((slice.partition, (Arc::clone(part), seg_base + si, rs, re)));
                    }
                }
            }
            for (_worker, list) in self.cluster.route_tagged(items)? {
                worker_lists.push(list);
            }
        }

        let batch = self.batch_kernel_calls;
        let net = self.cluster.net;
        let tasks: Vec<_> = worker_lists
            .into_iter()
            .map(|list| {
                let backend = Arc::clone(&self.backend);
                move || -> Result<Vec<(usize, Moments)>> {
                    net.message(); // task dispatch to this worker
                    let mut out = Vec::with_capacity(list.len());
                    for (part, seg, rs, re) in &list {
                        let m =
                            slice_moments(backend.as_ref(), part, *rs, *re, column, batch)?;
                        out.push((*seg, m));
                    }
                    net.message(); // result return
                    Ok(out)
                }
            })
            .collect();
        let n_tasks = tasks.len();
        let partials = self.ctx.pool().scope_execute(tasks);

        let mut seg_moments = vec![Moments::EMPTY; segments.len()];
        for partial in partials {
            for (seg, m) in partial? {
                seg_moments[seg] = seg_moments[seg].merge(m);
            }
        }
        // Demux: a query's moments are the merge of the elementary
        // segments it covers (each segment knows its covering sources).
        let mut per_query = vec![Moments::EMPTY; queries.len()];
        for (seg, srcs) in seg_sources.iter().enumerate() {
            for &qi in srcs {
                per_query[qi] = per_query[qi].merge(seg_moments[seg]);
            }
        }
        let stats = per_query
            .into_iter()
            .enumerate()
            .map(|(i, m)| {
                PeriodStats::from_moments(m).ok_or_else(|| {
                    OsebaError::InvalidRange(format!(
                        "query {i} selects no rows in [{}, {}]",
                        queries[i].lo, queries[i].hi
                    ))
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let store_delta = ds
            .store()
            .map(|s| s.counters().since(&store_before))
            .unwrap_or_default();
        let report = BatchReport {
            queries: queries.len(),
            merged_ranges: plan.len(),
            segments: segments.len(),
            partitions_touched,
            tasks: n_tasks,
            faults: store_delta.faults,
            evictions: store_delta.evictions,
            segment_bytes_read: store_delta.segment_bytes_read,
            secs: timer.secs(),
        };
        Ok((stats, report))
    }

    /// Route owned slice tasks to workers, execute, merge, finalize.
    fn run_stats_tasks(
        &self,
        owned: Vec<(Arc<crate::storage::Partition>, crate::index::PartitionSlice)>,
        column: usize,
    ) -> Result<PeriodStats> {
        let by_slice: std::collections::HashMap<usize, Arc<crate::storage::Partition>> =
            owned.iter().map(|(p, s)| (s.partition, Arc::clone(p))).collect();
        let groups = self
            .cluster
            .route(&owned.iter().map(|(_, s)| *s).collect::<Vec<_>>())?;

        let batch = self.batch_kernel_calls;
        let net = self.cluster.net;
        let tasks: Vec<_> = groups
            .into_iter()
            .map(|(_w, slices)| {
                let backend = Arc::clone(&self.backend);
                let parts: Vec<_> = slices
                    .iter()
                    .map(|s| (Arc::clone(&by_slice[&s.partition]), *s))
                    .collect();
                move || -> Result<Moments> {
                    net.message(); // task dispatch to this worker
                    let mut m = Moments::EMPTY;
                    for (part, s) in &parts {
                        m = m.merge(slice_moments(
                            backend.as_ref(),
                            part,
                            s.row_start,
                            s.row_end,
                            column,
                            batch,
                        )?);
                    }
                    net.message(); // result return
                    Ok(m)
                }
            })
            .collect();

        let partials = self.ctx.pool().scope_execute(tasks);
        let mut merged = Moments::EMPTY;
        for p in partials {
            merged = merged.merge(p?);
        }
        PeriodStats::from_moments(merged)
            .ok_or_else(|| OsebaError::InvalidRange("empty selection".into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AppConfig, ContextConfig};
    use crate::datagen::ClimateGen;
    use crate::runtime::NativeBackend;

    fn coord(workers: usize) -> Coordinator {
        let cfg = AppConfig {
            ctx: ContextConfig { num_workers: 4, memory_budget: None },
            cluster_workers: workers,
            ..Default::default()
        };
        Coordinator::new(&cfg, Arc::new(NativeBackend)).unwrap()
    }

    fn q_hours(lo: i64, hi: i64) -> RangeQuery {
        RangeQuery { lo: lo * 3600, hi: hi * 3600 }
    }

    #[test]
    fn default_and_oseba_agree_exactly() {
        let c = coord(3);
        let ds = c.load(ClimateGen::default().generate(30_000), 15).unwrap();
        let index = c.build_index(&ds, IndexKind::Cias).unwrap();
        for (lo, hi) in [(0, 100), (5_000, 12_000), (29_000, 29_999), (100, 25_000)] {
            let q = q_hours(lo, hi);
            let (d, filtered) = c.analyze_period_default(&ds, q, 0).unwrap();
            let o = c.analyze_period_oseba(&ds, index.as_ref(), q, 0).unwrap();
            assert_eq!(d.count, o.count, "q={q:?}");
            assert_eq!(d.max, o.max);
            assert_eq!(d.min, o.min);
            assert!((d.mean - o.mean).abs() < 1e-6);
            assert!((d.std - o.std).abs() < 1e-6);
            c.context().unpersist(&filtered);
        }
    }

    #[test]
    fn oseba_touches_fewer_partitions() {
        let c = coord(2);
        let ds = c.load(ClimateGen::default().generate(30_000), 15).unwrap();
        let index = c.build_index(&ds, IndexKind::Cias).unwrap();
        let before = c.context().counters();
        let q = q_hours(0, 1_000); // first partition only
        c.analyze_period_oseba(&ds, index.as_ref(), q, 0).unwrap();
        let after = c.context().counters();
        assert_eq!(after.partitions_scanned, before.partitions_scanned);
        assert_eq!(after.partitions_targeted - before.partitions_targeted, 1);
    }

    #[test]
    fn default_grows_memory_oseba_does_not() {
        let c = coord(2);
        let ds = c.load(ClimateGen::default().generate(20_000), 10).unwrap();
        let index = c.build_index(&ds, IndexKind::Cias).unwrap();
        let base = c.context().memory_used();
        let q = q_hours(2_000, 9_000);
        c.analyze_period_oseba(&ds, index.as_ref(), q, 0).unwrap();
        assert_eq!(c.context().memory_used(), base);
        let (_, _filtered) = c.analyze_period_default(&ds, q, 0).unwrap();
        assert!(c.context().memory_used() > base);
    }

    #[test]
    fn survives_worker_failure() {
        let c = coord(4);
        let ds = c.load(ClimateGen::default().generate(20_000), 12).unwrap();
        let index = c.build_index(&ds, IndexKind::Cias).unwrap();
        let q = q_hours(1_000, 15_000);
        let before = c.analyze_period_oseba(&ds, index.as_ref(), q, 0).unwrap();
        c.cluster().kill_worker(2).unwrap();
        let after = c.analyze_period_oseba(&ds, index.as_ref(), q, 0).unwrap();
        assert_eq!(before.count, after.count);
        assert_eq!(before.max, after.max);
        assert!((before.mean - after.mean).abs() < 1e-9);
    }

    #[test]
    fn table_and_cias_agree_via_coordinator() {
        let c = coord(3);
        let ds = c.load(ClimateGen::default().generate(25_000), 9).unwrap();
        let t = c.build_index(&ds, IndexKind::Table).unwrap();
        let s = c.build_index(&ds, IndexKind::Cias).unwrap();
        let q = q_hours(3_000, 17_000);
        let a = c.analyze_period_oseba(&ds, t.as_ref(), q, 2).unwrap();
        let b = c.analyze_period_oseba(&ds, s.as_ref(), q, 2).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn miss_query_errors() {
        let c = coord(2);
        let ds = c.load(ClimateGen::default().generate(1_000), 4).unwrap();
        let index = c.build_index(&ds, IndexKind::Cias).unwrap();
        let q = RangeQuery { lo: i64::MAX - 5, hi: i64::MAX };
        assert!(c.analyze_period_oseba(&ds, index.as_ref(), q, 0).is_err());
        assert!(c.analyze_period_default(&ds, q, 0).is_err());
    }

    #[test]
    fn unbatched_matches_batched() {
        let mut c = coord(2);
        let ds = c.load(ClimateGen::default().generate(15_000), 6).unwrap();
        let index = c.build_index(&ds, IndexKind::Cias).unwrap();
        let q = q_hours(500, 11_000);
        let a = c.analyze_period_oseba(&ds, index.as_ref(), q, 0).unwrap();
        c.batch_kernel_calls = false;
        let b = c.analyze_period_oseba(&ds, index.as_ref(), q, 0).unwrap();
        assert_eq!(a, b);
    }

    fn assert_stats_close(a: &PeriodStats, b: &PeriodStats, ctx: &str) {
        // Exact on count/extremes; mean/std tolerate the f32 kernel
        // partials regrouping when blocks are split at segment boundaries
        // (same tolerance the default-vs-oseba equivalence tests use).
        assert_eq!(a.count, b.count, "{ctx}");
        assert_eq!(a.max, b.max, "{ctx}");
        assert_eq!(a.min, b.min, "{ctx}");
        assert!((a.mean - b.mean).abs() < 1e-6, "{ctx}: {} vs {}", a.mean, b.mean);
        assert!((a.std - b.std).abs() < 1e-6, "{ctx}: {} vs {}", a.std, b.std);
    }

    #[test]
    fn coordinator_is_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Coordinator>();
    }

    #[test]
    fn analyze_batch_matches_individual_queries() {
        let c = coord(3);
        let ds = c.load(ClimateGen::default().generate(30_000), 15).unwrap();
        let index = c.build_index(&ds, IndexKind::Cias).unwrap();
        // Overlapping, adjacent, contained and disjoint queries together.
        let qs = vec![
            q_hours(0, 4_000),
            q_hours(2_000, 9_000),
            q_hours(3_000, 3_500),
            q_hours(9_001, 12_000),
            q_hours(20_000, 22_000),
        ];
        let batch = c.analyze_batch(&ds, index.as_ref(), &qs, 0).unwrap();
        assert_eq!(batch.len(), qs.len());
        for (i, q) in qs.iter().enumerate() {
            let single = c.analyze_period_oseba(&ds, index.as_ref(), *q, 0).unwrap();
            assert_stats_close(&batch[i], &single, &format!("query {i}"));
        }
    }

    #[test]
    fn overlapping_batch_targets_each_partition_once() {
        let c = coord(3);
        let ds = c.load(ClimateGen::default().generate(30_000), 15).unwrap();
        let index = c.build_index(&ds, IndexKind::Cias).unwrap();
        // Six mutually-overlapping queries whose union is hours [0, 7500].
        let qs: Vec<RangeQuery> =
            (0..6).map(|i| q_hours(i * 500, 5_000 + i * 500)).collect();
        let union = q_hours(0, 7_500);
        let expect = index.lookup(union).len();
        assert!(expect > 1, "several partitions intersect");

        let before = c.context().counters();
        let (stats, report) =
            c.analyze_batch_with_report(&ds, index.as_ref(), &qs, 0).unwrap();
        let after = c.context().counters();

        // Each intersecting partition is targeted exactly once for the
        // whole batch — not once per query.
        assert_eq!(after.partitions_targeted - before.partitions_targeted, expect);
        assert_eq!(after.partitions_scanned, before.partitions_scanned, "no scans");
        assert_eq!(report.merged_ranges, 1);
        assert_eq!(report.queries, 6);
        assert_eq!(report.partitions_touched, expect);
        assert_eq!(stats.len(), 6);
    }

    #[test]
    fn analyze_batch_empty_and_miss_cases() {
        let c = coord(2);
        let ds = c.load(ClimateGen::default().generate(5_000), 4).unwrap();
        let index = c.build_index(&ds, IndexKind::Cias).unwrap();
        // Empty batch: trivially fine.
        let (stats, report) =
            c.analyze_batch_with_report(&ds, index.as_ref(), &[], 0).unwrap();
        assert!(stats.is_empty());
        assert_eq!(report.merged_ranges, 0);
        // Inverted range: rejected up front.
        let bad = RangeQuery { lo: 10, hi: 5 };
        assert!(c.analyze_batch(&ds, index.as_ref(), &[bad], 0).is_err());
        // A query that misses the dataset errors, naming the query.
        let miss = RangeQuery { lo: i64::MAX - 5, hi: i64::MAX };
        let err = c
            .analyze_batch(&ds, index.as_ref(), &[q_hours(0, 100), miss], 0)
            .unwrap_err();
        assert!(err.to_string().contains("query 1"), "got: {err}");
    }

    #[test]
    fn analyze_batch_concurrent_callers_agree() {
        let c = coord(4);
        let ds = c.load(ClimateGen::default().generate(20_000), 10).unwrap();
        let index = c.build_index(&ds, IndexKind::Cias).unwrap();
        let qs = vec![q_hours(0, 5_000), q_hours(3_000, 9_000), q_hours(15_000, 18_000)];
        let expected = c.analyze_batch(&ds, index.as_ref(), &qs, 0).unwrap();
        std::thread::scope(|s| {
            for _ in 0..4 {
                let (c, ds, index, qs, expected) = (&c, &ds, &*index, &qs, &expected);
                s.spawn(move || {
                    for _ in 0..3 {
                        let got = c.analyze_batch(ds, index, qs, 0).unwrap();
                        for (g, e) in got.iter().zip(expected) {
                            assert_stats_close(g, e, "concurrent");
                        }
                    }
                });
            }
        });
    }

    #[test]
    fn tiered_analysis_matches_resident_and_counts_faults() {
        let dir = crate::testing::temp_dir("coord-tiered");
        // Resident reference run.
        let c = coord(3);
        let ds = c.load(ClimateGen::default().generate(30_000), 15).unwrap();
        let index = c.build_index(&ds, IndexKind::Cias).unwrap();
        let qs = vec![q_hours(0, 3_000), q_hours(2_000, 5_000)];
        let want = c.analyze_batch(&ds, index.as_ref(), &qs, 0).unwrap();

        // Same workload, tiered, with a budget of ~3 of 15 partitions.
        let batch = ClimateGen::default().generate(30_000);
        let one = crate::storage::partition_batch_uniform(&batch, 2_000).unwrap()[0].bytes();
        let cfg = AppConfig {
            ctx: ContextConfig { num_workers: 4, memory_budget: Some(3 * one + one / 2) },
            cluster_workers: 3,
            ..Default::default()
        };
        let ct = Coordinator::new(&cfg, Arc::new(NativeBackend)).unwrap();
        let tds = ct.load_tiered(batch, 15, &dir).unwrap();
        assert!(tds.is_tiered());
        let tindex = ct.build_index(&tds, IndexKind::Cias).unwrap();
        let (got, report) =
            ct.analyze_batch_with_report(&tds, tindex.as_ref(), &qs, 0).unwrap();
        for (g, e) in got.iter().zip(&want) {
            assert_stats_close(g, e, "tiered batch");
        }
        assert!(report.faults > 0, "cold partitions must fault in");
        assert!(report.segment_bytes_read > 0);

        // Single-query Oseba path works tiered too.
        let single = ct
            .analyze_period_oseba(&tds, tindex.as_ref(), q_hours(0, 3_000), 0)
            .unwrap();
        assert_stats_close(&single, &want[0], "tiered single");
        ct.context().unpersist(&tds);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn live_analysis_matches_batch_loaded() {
        let c = coord(3);
        let ds = c.load(ClimateGen::default().generate(20_000), 10).unwrap();
        let index = c.build_index(&ds, IndexKind::Cias).unwrap();

        // Same data streamed into a live dataset with the same layout.
        let live = c
            .create_live(
                Schema::climate(),
                LiveConfig { rows_per_partition: 2_000, max_asl: 8 },
            )
            .unwrap();
        for chunk in crate::ingest::chunk_batch(&ClimateGen::default().generate(20_000), 777)
        {
            live.append(chunk).unwrap();
        }
        live.flush().unwrap();

        let q = q_hours(1_000, 15_000);
        let want = c.analyze_period_oseba(&ds, index.as_ref(), q, 0).unwrap();
        let (got, epoch) = c.analyze_live(&live, q, 0).unwrap();
        assert!(epoch > 0);
        assert_stats_close(&got, &want, "live vs loaded");

        let qs = vec![q_hours(0, 4_000), q_hours(3_000, 9_000)];
        let want: Vec<PeriodStats> = qs
            .iter()
            .map(|q| c.analyze_period_oseba(&ds, index.as_ref(), *q, 0).unwrap())
            .collect();
        let (got, report, batch_epoch) = c.analyze_live_batch(&live, &qs, 0).unwrap();
        assert_eq!(report.queries, 2);
        assert_eq!(batch_epoch, epoch, "no appends between the two calls");
        for (g, w) in got.iter().zip(&want) {
            assert_stats_close(g, w, "live batch");
        }
        live.close();
    }

    #[test]
    fn live_analysis_on_empty_dataset_errors() {
        let c = coord(2);
        let live = c.create_live(Schema::climate(), LiveConfig::default()).unwrap();
        assert!(c.analyze_live(&live, q_hours(0, 10), 0).is_err());
        assert!(c.analyze_live_batch(&live, &[q_hours(0, 10)], 0).is_err());
        live.close();
    }

    #[test]
    fn analyze_batch_survives_worker_failure() {
        let c = coord(4);
        let ds = c.load(ClimateGen::default().generate(20_000), 12).unwrap();
        let index = c.build_index(&ds, IndexKind::Cias).unwrap();
        let qs = vec![q_hours(0, 8_000), q_hours(6_000, 15_000)];
        let before = c.analyze_batch(&ds, index.as_ref(), &qs, 0).unwrap();
        c.cluster().kill_worker(1).unwrap();
        let after = c.analyze_batch(&ds, index.as_ref(), &qs, 0).unwrap();
        for (a, b) in before.iter().zip(&after) {
            assert_stats_close(a, b, "failover");
        }
    }
}
