//! The AOT runtime: artifact manifests, the PJRT execution engine, the
//! kernel service thread, and the backend abstraction the analyses target.
//!
//! Python never runs here — `artifacts/*.hlo.txt` were lowered once at
//! build time by `python/compile/aot.py` (see DESIGN.md §3).

pub mod artifacts;
pub mod backend;
pub mod native;
#[cfg(feature = "xla")]
pub mod pjrt;
pub mod service;

pub use artifacts::Manifest;
pub use backend::AnalysisBackend;
pub use native::NativeBackend;
#[cfg(feature = "xla")]
pub use pjrt::PjRtRuntime;
pub use service::{spawn as spawn_kernel_service, KernelHandle, ServiceStats};

use std::sync::Arc;

use crate::config::BackendKind;
use crate::error::Result;

/// Construct the configured backend: `Hlo` spawns the kernel service over
/// `artifacts_dir` (precompiling all entries); `Native` needs nothing.
pub fn make_backend(kind: BackendKind, artifacts_dir: &str) -> Result<Arc<dyn AnalysisBackend>> {
    match kind {
        BackendKind::Native => Ok(Arc::new(NativeBackend)),
        BackendKind::Hlo => Ok(Arc::new(spawn_kernel_service(artifacts_dir, true)?)),
    }
}
