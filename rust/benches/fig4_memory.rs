//! **Fig 4 reproduction**: accumulated memory after each of the five
//! period-analysis phases, default (filter + cache) vs Oseba (CIAS).
//!
//! Paper result (480 MB on Marmot/Spark 1.0.2): default climbs to
//! ~1800 MB ≈ 3.8× raw; Oseba stays ~flat; ratio ≈2× at phase 3, ≈3× at
//! phase 5. Our substrate stores filtered RDDs as compact columnar blocks
//! (no JVM object overhead), so the measured growth is the *materialized
//! selection* itself; the `spark-equiv` column applies the 2.5× cached-
//! object expansion Spark's own tuning guide cites, which is what the
//! paper's cluster actually paid per cached byte.
//!
//! Run: `cargo bench --bench fig4_memory` (OSEBA_BYTES to rescale).

mod common;

use oseba::analysis::five_periods;
use oseba::config::parse_bytes;
use oseba::coordinator::{run_session, IndexKind, Method};
use oseba::util::humansize;

const SPARK_OBJECT_OVERHEAD: f64 = 2.5;

fn main() {
    let bytes = std::env::var("OSEBA_BYTES")
        .ok()
        .map(|v| parse_bytes(&v).expect("OSEBA_BYTES"))
        .unwrap_or(64 << 20);
    let backend = common::backend_kind();
    let periods = five_periods();

    oseba::bench::section(&format!(
        "Fig 4: memory per phase ({} raw, 15 partitions, backend {:?})",
        humansize::bytes(bytes),
        backend
    ));

    let mut series = Vec::new();
    for method in [Method::Default, Method::Oseba] {
        let (coord, ds, raw) = common::setup(bytes, 15, backend);
        let report = run_session(&coord, &ds, method, IndexKind::Cias, &periods, 0, false)
            .expect("session");
        series.push((method, report, raw));
    }
    let (_, default, raw) = &series[0];
    let (_, oseba, _) = &series[1];

    println!(
        "{:<7} {:>12} {:>12} {:>12} {:>9} {:>11} {:>13}",
        "phase", "default", "oseba", "spark-equiv", "def/raw", "def/oseba", "paper def/raw"
    );
    // Paper curve eyeballed from Fig 4 (480 MB raw → ~700/950/1250/1500/1800 MB).
    let paper_ratio = [1.46, 1.98, 2.60, 3.13, 3.75];
    let dm = default.metrics.memory_series();
    let om = oseba.metrics.memory_series();
    for i in 0..5 {
        let growth = dm[i] - om[i];
        let spark_equiv = om[i] as f64 + growth as f64 * SPARK_OBJECT_OVERHEAD;
        println!(
            "{:<7} {:>12} {:>12} {:>12} {:>8.2}x {:>10.2}x {:>12.2}x",
            i + 1,
            humansize::bytes(dm[i]),
            humansize::bytes(om[i]),
            humansize::bytes(spark_equiv as usize),
            dm[i] as f64 / *raw as f64,
            dm[i] as f64 / om[i] as f64,
            paper_ratio[i]
        );
    }

    // Shape assertions (the reproduction contract).
    assert!(dm.windows(2).all(|w| w[1] > w[0]), "default memory must grow");
    assert!(om.windows(2).all(|w| w[0] == w[1]), "oseba memory must stay flat");
    assert!(dm[4] as f64 / om[4] as f64 > 1.3, "phase-5 ratio");
    println!("\nshape check: default monotone ✓, oseba flat ✓, final ratio {:.2}x ✓",
        dm[4] as f64 / om[4] as f64);
    println!("index footprint: oseba={} bytes", oseba.index_bytes);

    use oseba::util::json::Json;
    let series_json = |xs: &[usize]| {
        Json::arr(xs.iter().map(|&b| Json::num(b as f64)).collect())
    };
    common::write_bench_json(
        "fig4_memory",
        Json::obj(vec![
            ("bench", Json::str("fig4_memory")),
            ("raw_bytes", Json::num(*raw as f64)),
            ("default_memory_bytes", series_json(&dm)),
            ("oseba_memory_bytes", series_json(&om)),
            ("final_ratio", Json::num(dm[4] as f64 / om[4] as f64)),
            ("index_bytes", Json::num(oseba.index_bytes as f64)),
        ]),
    );
}
