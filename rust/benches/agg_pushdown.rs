//! **Aggregate-pushdown bench**: stats queries over a tiered dataset ~4×
//! the memory budget, comparing the sketch-answered plan (covered
//! partitions merged from super-index aggregate sketches) against the
//! pre-PR scan plan (every targeted partition resolved + scanned).
//!
//! Two workloads:
//! * **wide covered** — a range fully containing every partition: the
//!   sketch path must read **0 segment bytes and fault 0 partitions in**,
//!   while the scan path faults the whole dataset through the budget;
//! * **narrow edge-heavy** — ranges that only ever partially overlap
//!   partitions: no coverage exists, both arms degenerate to the same
//!   edge scans (the no-regression arm).
//!
//! Results are asserted identical (`PeriodStats` equality) before any
//! timing. Emits `BENCH_agg_pushdown.json` for the perf trajectory.
//!
//! Run: `cargo bench --bench agg_pushdown`
//! (OSEBA_AGG_BUDGET rescales; dataset is 4× the budget.)

mod common;

use oseba::bench::{bench, section, table, BenchConfig};
use oseba::config::{parse_bytes, BackendKind, ContextConfig};
use oseba::coordinator::{
    plan_query_opts, Coordinator, PhysicalPlan, PlanOptions, Query, QueryOutput,
};
use oseba::engine::Dataset;
use oseba::index::RangeQuery;
use oseba::runtime::make_backend;
use oseba::util::humansize;
use oseba::util::json::Json;

const PARTITIONS: usize = 32;

fn coordinator(budget: usize) -> Coordinator {
    let mut cfg = common::app_cfg(BackendKind::Native);
    cfg.ctx = ContextConfig { num_workers: 4, memory_budget: Some(budget) };
    let be = make_backend(cfg.backend, &cfg.artifacts_dir).expect("backend");
    Coordinator::new(&cfg, be).expect("coordinator")
}

fn run_stats(c: &Coordinator, ds: &Dataset, plan: &PhysicalPlan, q: &Query) -> oseba::analysis::PeriodStats {
    match c.execute_physical(ds, plan, q).expect("execute") {
        QueryOutput::Stats(s) => s,
        other => panic!("stats output, got {other:?}"),
    }
}

fn main() {
    let budget = std::env::var("OSEBA_AGG_BUDGET")
        .ok()
        .map(|v| parse_bytes(&v).expect("OSEBA_AGG_BUDGET"))
        .unwrap_or(8 << 20);
    let raw = 4 * budget;
    let dir =
        std::env::temp_dir().join(format!("oseba-agg-bench-{}", std::process::id()));

    section(&format!(
        "Aggregate pushdown: {} tiered dataset under a {} budget ({} partitions)",
        humansize::bytes(raw),
        humansize::bytes(budget),
        PARTITIONS
    ));

    let coord = coordinator(budget);
    let batch = oseba::datagen::ClimateGen::default().generate_bytes(raw);
    let rows = batch.rows();
    let ds = coord.load_tiered(batch, PARTITIONS, &dir).expect("tiered load");
    let store = ds.store().expect("tiered").clone();
    let index = coord
        .build_index(&ds, oseba::coordinator::IndexKind::Cias)
        .expect("index");

    let (kmin, kmax) = (ds.key_min().unwrap(), ds.key_max().unwrap());
    let span = kmax - kmin;
    // Wide covered workload: the whole key span — every partition is
    // fully contained, so the sketch path reads nothing.
    let wide = Query::stats(RangeQuery { lo: kmin, hi: kmax }, 0);
    // Narrow edge-heavy workload: 8 slivers each ~1/300 of the span,
    // straddling partition boundaries — nothing is ever covered.
    let part_span = span / PARTITIONS as i64;
    let narrow: Vec<Query> = (1..=8)
        .map(|i| {
            let mid = kmin + part_span * (4 * i) as i64;
            Query::stats(RangeQuery { lo: mid - span / 600, hi: mid + span / 600 }, 0)
        })
        .collect();

    let on = PlanOptions {
        zone_pruning: true,
        filter_pruning: true,
        agg_pushdown: true,
        block_pruning: true,
    };
    let off = PlanOptions {
        zone_pruning: true,
        filter_pruning: true,
        agg_pushdown: false,
        block_pruning: true,
    };
    let cfg = BenchConfig::from_env();
    let mut results = Vec::new();
    let mut json_arms = Vec::new();

    for (workload, queries) in
        [("wide-covered", vec![wide.clone()]), ("narrow-edges", narrow.clone())]
    {
        for (arm, opts) in [("sketch", on), ("scan (pre-PR)", off)] {
            let plans: Vec<(Query, PhysicalPlan)> = queries
                .iter()
                .map(|q| {
                    (q.clone(), plan_query_opts(&ds, index.as_ref(), q, opts).expect("plan"))
                })
                .collect();
            let agg_answered: usize =
                plans.iter().map(|(_, p)| p.explain.agg_answered).sum();
            let rows_avoided: usize =
                plans.iter().map(|(_, p)| p.explain.rows_avoided).sum();

            // Counters over one cold run.
            store.shrink(usize::MAX).expect("evict all");
            let before = store.counters();
            let mut counts = 0u64;
            for (q, plan) in &plans {
                counts += run_stats(&coord, &ds, plan, q).count;
            }
            let delta = store.counters().since(&before);

            let r = bench(&cfg, &format!("{workload} / {arm}"), || {
                store.shrink(usize::MAX).expect("evict all");
                for (q, plan) in &plans {
                    run_stats(&coord, &ds, plan, q);
                }
            });
            println!(
                "  {workload} / {arm}: {} faults, {} read, agg-answered {agg_answered}, \
                 rows selected {counts}",
                delta.faults,
                humansize::bytes(delta.segment_bytes_read),
            );
            json_arms.push(Json::obj(vec![
                ("workload", Json::str(workload)),
                ("arm", Json::str(arm)),
                ("faults", Json::num(delta.faults as f64)),
                ("segment_bytes_read", Json::num(delta.segment_bytes_read as f64)),
                ("agg_answered", Json::num(agg_answered as f64)),
                ("rows_avoided", Json::num(rows_avoided as f64)),
                ("rows_selected", Json::num(counts as f64)),
                ("secs_mean", Json::num(r.summary.mean)),
                ("secs_p50", Json::num(r.summary.p50)),
                ("secs_p95", Json::num(r.summary.p95)),
            ]));
            results.push(r);
        }
    }
    println!("\n{}", table(&results));

    // Correctness gate: identical PeriodStats on both arms, cold cache.
    let wide_on = plan_query_opts(&ds, index.as_ref(), &wide, on).expect("plan");
    let wide_off = plan_query_opts(&ds, index.as_ref(), &wide, off).expect("plan");
    store.shrink(usize::MAX).expect("evict all");
    let got = run_stats(&coord, &ds, &wide_on, &wide);
    store.shrink(usize::MAX).expect("evict all");
    let want = run_stats(&coord, &ds, &wide_off, &wide);
    assert_eq!(got, want, "sketch answers must be identical to scans");
    for q in &narrow {
        let p_on = plan_query_opts(&ds, index.as_ref(), q, on).expect("plan");
        let p_off = plan_query_opts(&ds, index.as_ref(), q, off).expect("plan");
        assert_eq!(run_stats(&coord, &ds, &p_on, q), run_stats(&coord, &ds, &p_off, q));
    }

    // Acceptance gate (the reproduction contract): on the fully-covered
    // workload the sketch arm reads NOTHING — 0 faults, 0 segment bytes —
    // while the pre-PR scan arm pays real I/O.
    let f = |j: &Json, k: &str| j.get(k).and_then(|v| v.as_f64()).unwrap();
    let (sketch, scan) = (&json_arms[0], &json_arms[1]);
    assert_eq!(f(sketch, "faults"), 0.0, "covered workload must fault nothing in");
    assert_eq!(f(sketch, "segment_bytes_read"), 0.0);
    assert!(f(scan, "faults") > 0.0, "the scan arm pays the fault-in");
    assert!(f(scan, "segment_bytes_read") > 0.0);
    assert_eq!(f(sketch, "agg_answered"), PARTITIONS as f64);
    println!(
        "covered workload: sketch 0 faults / 0 bytes vs scan {} faults / {}",
        f(scan, "faults"),
        humansize::bytes(f(scan, "segment_bytes_read") as usize)
    );

    common::write_bench_json(
        "agg_pushdown",
        Json::obj(vec![
            ("bench", Json::str("agg_pushdown")),
            ("raw_bytes", Json::num(raw as f64)),
            ("budget_bytes", Json::num(budget as f64)),
            ("partitions", Json::num(PARTITIONS as f64)),
            ("rows", Json::num(rows as f64)),
            ("arms", Json::arr(json_arms)),
        ]),
    );

    coord.context().unpersist(&ds);
    let _ = std::fs::remove_dir_all(&dir);
}
