//! Events analysis example (paper §II: "in telephone security, fraud can
//! be detected by comparing the distributions of typical phone calls and
//! of calls made from a stolen phone").
//!
//! Generates call-detail records with a known fraud window, selects the
//! suspect period through the index, and compares call-duration and
//! destination-prefix histograms against a baseline period.
//!
//! ```bash
//! cargo run --release --example fraud_events
//! ```

use oseba::config::{AppConfig, BackendKind};
use oseba::coordinator::Coordinator;
use oseba::datagen::CdrGen;
use oseba::index::{Cias, ContentIndex, RangeQuery};
use oseba::runtime::make_backend;

/// L1 (total-variation-like) distance between normalized histograms.
fn tv_distance(a: &[f32], b: &[f32]) -> f64 {
    let (sa, sb): (f32, f32) = (a.iter().sum(), b.iter().sum());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| ((x / sa) as f64 - (y / sb) as f64).abs())
        .sum::<f64>()
        / 2.0
}

fn sparkline(h: &[f32]) -> String {
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let max = h.iter().cloned().fold(0.0f32, f32::max).max(1.0);
    h.chunks(2)
        .map(|c| {
            let v = (c.iter().sum::<f32>() / c.len() as f32) / max;
            BARS[((v * 7.0).round() as usize).min(7)]
        })
        .collect()
}

fn main() -> oseba::Result<()> {
    let mut cfg = AppConfig::default();
    if !std::path::Path::new(&cfg.artifacts_dir).join("manifest.json").exists() {
        eprintln!("(artifacts not built; using the native backend)");
        cfg.backend = BackendKind::Native;
    }
    let backend = make_backend(cfg.backend, &cfg.artifacts_dir)?;
    let coord = Coordinator::new(&cfg, backend)?;

    // A week of call records at one per 30 s; phone stolen during day 5.
    let step = 30i64;
    let day_rows = (24 * 3600 / step) as usize;
    let fraud = (5 * day_rows, 5 * day_rows + day_rows / 2);
    let gen = CdrGen { fraud_rows: Some(fraud), ..Default::default() };
    let ds = coord.load(gen.generate(7 * day_rows), 14)?;
    let index = Cias::build(ds.partitions())?;
    let an = coord.analyzer();

    let dur = ds.schema().column_index("duration")?;
    let prefix = ds.schema().column_index("dest_prefix")?;

    let range = |lo_row: usize, hi_row: usize| {
        RangeQuery::new(lo_row as i64 * step, (hi_row as i64 - 1) * step).unwrap()
    };
    let baseline_q = range(0, 5 * day_rows);
    let suspect_q = range(fraud.0, fraud.1);

    let vb_pins = coord.context().select_slices(&ds, &index.lookup(baseline_q), baseline_q)?;
    let vs_pins = coord.context().select_slices(&ds, &index.lookup(suspect_q), suspect_q)?;
    let (vb, vs) = (vb_pins.views(), vs_pins.views());

    println!("baseline: {} calls | suspect window: {} calls",
        vb_pins.rows(),
        vs_pins.rows());

    let hb_dur = an.histogram(&vb, dur, 0.0, 3600.0)?;
    let hs_dur = an.histogram(&vs, dur, 0.0, 3600.0)?;
    let hb_pre = an.histogram(&vb, prefix, 0.0, 100.0)?;
    let hs_pre = an.histogram(&vs, prefix, 0.0, 100.0)?;

    println!("\ncall duration distribution (0..3600 s):");
    println!("  baseline {}", sparkline(&hb_dur));
    println!("  suspect  {}", sparkline(&hs_dur));
    let d_dur = tv_distance(&hb_dur, &hs_dur);
    println!("  TV distance: {d_dur:.3}");

    println!("\ndestination prefix distribution (0..100):");
    println!("  baseline {}", sparkline(&hb_pre));
    println!("  suspect  {}", sparkline(&hs_pre));
    let d_pre = tv_distance(&hb_pre, &hs_pre);
    println!("  TV distance: {d_pre:.3}");

    // Detection rule from the paper's motivation: distribution shift.
    let flagged = d_dur > 0.2 || d_pre > 0.2;
    println!("\nfraud flagged: {flagged} (thresholds: 0.2)");
    assert!(flagged, "known fraud window must be detected");

    // Control: a clean day must NOT be flagged.
    let control_q = range(2 * day_rows, 3 * day_rows);
    let vc_pins = coord.context().select_slices(&ds, &index.lookup(control_q), control_q)?;
    let vc = vc_pins.views();
    let hc = an.histogram(&vc, dur, 0.0, 3600.0)?;
    let d_ctl = tv_distance(&hb_dur, &hc);
    println!("control day TV distance: {d_ctl:.3} (flagged: {})", d_ctl > 0.2);
    assert!(d_ctl < 0.2, "clean day should not be flagged");
    Ok(())
}
