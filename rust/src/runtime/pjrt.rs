//! Thread-local PJRT runtime: loads HLO-text artifacts, compiles them once
//! on the CPU client, and executes them with `Literal` inputs.
//!
//! `PjRtClient` wraps an `Rc` internally, so this type is deliberately
//! **not** `Send`/`Sync`; cross-thread access goes through the
//! [`crate::runtime::service::KernelService`] thread that owns one of
//! these (the single-device execution queue).

use std::collections::HashMap;
use std::path::Path;

use crate::error::{OsebaError, Result};
use crate::runtime::artifacts::Manifest;

/// One compiled executable per manifest entry, compiled lazily (or eagerly
/// via [`PjRtRuntime::precompile_all`]).
pub struct PjRtRuntime {
    client: xla::PjRtClient,
    manifest: Manifest,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Cumulative number of kernel executions (perf accounting).
    pub executions: u64,
}

impl PjRtRuntime {
    /// Create a CPU-client runtime over the artifacts in `dir`.
    pub fn new(dir: impl AsRef<Path>) -> Result<PjRtRuntime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(PjRtRuntime { client, manifest, executables: HashMap::new(), executions: 0 })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile every manifest entry now (deterministic first-query latency).
    pub fn precompile_all(&mut self) -> Result<()> {
        let names: Vec<String> = self.manifest.entries.keys().cloned().collect();
        for n in names {
            self.ensure_compiled(&n)?;
        }
        Ok(())
    }

    fn ensure_compiled(&mut self, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let entry = self.manifest.entry(name)?.clone();
        let path_str = entry.path.to_str().ok_or_else(|| {
            OsebaError::Artifact(format!("non-utf8 artifact path {:?}", entry.path))
        })?;
        let proto = xla::HloModuleProto::from_text_file(path_str).map_err(|e| {
            OsebaError::Artifact(format!("parsing {} failed: {e}", entry.path.display()))
        })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute entry `name` with `args`, returning the flattened result
    /// tuple (aot.py lowers everything with `return_tuple=True`).
    pub fn execute(&mut self, name: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.ensure_compiled(name)?;
        let entry = self.manifest.entry(name)?;
        if args.len() != entry.params.len() {
            return Err(OsebaError::Runtime(format!(
                "{name}: expected {} args, got {}",
                entry.params.len(),
                args.len()
            )));
        }
        let exe = self.executables.get(name).ok_or_else(|| {
            OsebaError::Runtime(format!("{name}: executable missing after compile"))
        })?;
        let mut out = exe.execute::<xla::Literal>(args)?;
        self.executions += 1;
        // Single device, single output: an N-tuple literal.
        let buf = out
            .pop()
            .and_then(|mut d| d.pop())
            .ok_or_else(|| OsebaError::Runtime(format!("{name}: empty result")))?;
        let lit = buf.to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// f32 scalars from a result tuple (the common kernel-output case).
    pub fn to_f32_scalars(results: &[xla::Literal]) -> Result<Vec<f32>> {
        results.iter().map(|l| Ok(l.to_vec::<f32>()?[0])).collect()
    }
}

/// Literal construction helpers shared by the service and tests.
pub mod lit {
    use super::*;

    /// f32 vector literal of exactly `len` elements (zero-padded/truncated
    /// guard: callers must already supply the right length).
    pub fn f32_vec(xs: &[f32]) -> xla::Literal {
        xla::Literal::vec1(xs)
    }

    /// i32 scalar literal.
    pub fn i32_scalar(v: i32) -> xla::Literal {
        xla::Literal::scalar(v)
    }

    /// f32 scalar literal.
    pub fn f32_scalar(v: f32) -> xla::Literal {
        xla::Literal::scalar(v)
    }

    /// Extract an f32 vector result.
    pub fn to_f32_vec(l: &xla::Literal) -> Result<Vec<f32>> {
        Ok(l.to_vec::<f32>()?)
    }
}
