//! Seeded violation: the reader guards the v2 (zones) and v3 (sketches)
//! upgrades but not v4 (filters), while VERSION says the writer can emit
//! v4.

pub const VERSION: u32 = 4;
pub const MIN_VERSION: u32 = 1;

pub fn to_json(version: u32) -> u32 {
    VERSION + version
}

pub fn from_json(version: u32) -> bool {
    if version < MIN_VERSION || version > VERSION {
        return false;
    }
    if version < 2 {
        // v1 upgrade path handled...
        return true;
    }
    if version < 3 {
        // ...v2 upgrade path handled...
        return true;
    }
    // ...but no `version < 4` guard — the seeded violation.
    true
}
