//! Core index vocabulary: range queries, partition slices, per-column
//! value-domain zone maps with the predicates that consult them, and the
//! [`ContentIndex`] trait both index implementations satisfy.

use crate::error::{OsebaError, Result};

/// An inclusive key-range selection `[lo, hi]` — the paper's "data ranging
/// from index i to j" (§III-A).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RangeQuery {
    /// Lower key bound, inclusive.
    pub lo: i64,
    /// Upper key bound, inclusive.
    pub hi: i64,
}

impl RangeQuery {
    /// Validate `lo <= hi`.
    pub fn new(lo: i64, hi: i64) -> Result<RangeQuery> {
        if lo > hi {
            return Err(OsebaError::InvalidRange(format!("lo {lo} > hi {hi}")));
        }
        Ok(RangeQuery { lo, hi })
    }
}

/// A targeted region of one partition: valid-row indices `[row_start,
/// row_end)` of partition `partition`. The unit of work the coordinator
/// dispatches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PartitionSlice {
    /// Target partition id.
    pub partition: usize,
    /// First valid row (inclusive).
    pub row_start: usize,
    /// One past the last valid row.
    pub row_end: usize,
}

impl PartitionSlice {
    /// Number of rows the slice covers.
    pub fn rows(&self) -> usize {
        self.row_end - self.row_start
    }
}

/// Content-aware metadata over a partitioned dataset: maps key ranges to
/// the partitions (and row ranges) that hold them, without touching data.
pub trait ContentIndex: Send + Sync {
    /// Human-readable implementation name (bench labels).
    fn name(&self) -> &'static str;

    /// All slices intersecting `q`, ordered by partition id; empty when the
    /// query misses the dataset entirely.
    fn lookup(&self, q: RangeQuery) -> Vec<PartitionSlice>;

    /// Resident metadata footprint in bytes — the §III space-complexity
    /// comparison (table: O(m); CIAS: O(1) + ASL).
    fn memory_bytes(&self) -> usize;

    /// Number of partitions the index covers.
    fn num_partitions(&self) -> usize;
}

/// Per-column value-domain statistics of one partition: min/max over the
/// non-NaN values plus a NaN count. This is the zone map predicate
/// pruning consults — pure metadata, so a cold (spilled) partition can be
/// ruled out *before* it is faulted in.
///
/// Zone maps ride next to [`PartitionMeta`] (in partitions, store slots
/// and the manifest) rather than inside it: the CIAS compressed region
/// keeps no per-partition metadata at all, so storing zones in the index
/// would reintroduce the O(m) footprint §III-B eliminates.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ZoneMap {
    /// Smallest non-NaN value (`f32::INFINITY` when none).
    pub min: f32,
    /// Largest non-NaN value (`f32::NEG_INFINITY` when none).
    pub max: f32,
    /// Number of NaN values in the column.
    pub nans: usize,
}

impl ZoneMap {
    /// The empty zone map (identity for [`ZoneMap::absorb`]).
    pub const EMPTY: ZoneMap =
        ZoneMap { min: f32::INFINITY, max: f32::NEG_INFINITY, nans: 0 };

    /// Zone map of a value slice (one pass; NaNs counted, not folded).
    pub fn of(values: &[f32]) -> ZoneMap {
        let mut z = ZoneMap::EMPTY;
        for &x in values {
            z.absorb(x);
        }
        z
    }

    /// Fold one value in.
    pub fn absorb(&mut self, x: f32) {
        if x.is_nan() {
            self.nans += 1;
        } else {
            self.min = self.min.min(x);
            self.max = self.max.max(x);
        }
    }

    /// Whether the column holds no non-NaN value.
    pub fn is_empty(&self) -> bool {
        self.min > self.max
    }
}

/// Zone maps for every value column of a partition's valid rows.
pub fn zone_maps_of(columns: &[Vec<f32>], rows: usize) -> Vec<ZoneMap> {
    columns.iter().map(|c| ZoneMap::of(&c[..rows.min(c.len())])).collect()
}

/// Comparison operator of a value predicate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PredOp {
    /// `column > value`
    Gt,
    /// `column >= value`
    Ge,
    /// `column < value`
    Lt,
    /// `column <= value`
    Le,
}

impl PredOp {
    /// The operator's source spelling (`">"`, `">="`, ...).
    pub fn symbol(&self) -> &'static str {
        match self {
            PredOp::Gt => ">",
            PredOp::Ge => ">=",
            PredOp::Lt => "<",
            PredOp::Le => "<=",
        }
    }
}

/// One `column OP value` predicate over a value column. A conjunction of
/// these is the `where` clause of a selective analysis; rows whose value
/// is NaN never match (IEEE comparison semantics).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ColumnPredicate {
    /// Index of the value column the predicate reads.
    pub column: usize,
    /// Comparison operator.
    pub op: PredOp,
    /// Comparison constant (finite).
    pub value: f32,
}

impl ColumnPredicate {
    /// Whether one row value satisfies the predicate (NaN never does).
    pub fn matches(&self, x: f32) -> bool {
        match self.op {
            PredOp::Gt => x > self.value,
            PredOp::Ge => x >= self.value,
            PredOp::Lt => x < self.value,
            PredOp::Le => x <= self.value,
        }
    }

    /// Whether *any* row of a partition could satisfy the predicate,
    /// judged from its zone map alone. `false` means the partition can be
    /// pruned without reading it: the zone bounds cover every non-NaN
    /// value, and NaN rows never match a comparison.
    pub fn satisfiable(&self, z: &ZoneMap) -> bool {
        match self.op {
            PredOp::Gt => z.max > self.value,
            PredOp::Ge => z.max >= self.value,
            PredOp::Lt => z.min < self.value,
            PredOp::Le => z.min <= self.value,
        }
    }
}

/// Whether a row (given by its per-column values accessor) satisfies every
/// predicate of a conjunction.
pub fn row_matches(preds: &[ColumnPredicate], value_of: impl Fn(usize) -> f32) -> bool {
    preds.iter().all(|p| p.matches(value_of(p.column)))
}

/// Whether a partition survives zone-map pruning for a conjunction:
/// every predicate must be satisfiable under the partition's zones.
pub fn zones_satisfiable(preds: &[ColumnPredicate], zones: &[ZoneMap]) -> bool {
    preds.iter().all(|p| match zones.get(p.column) {
        Some(z) => p.satisfiable(z),
        // Unknown zone (column out of range): never prune on it.
        None => true,
    })
}

/// Shared per-partition metadata record extracted at load time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PartitionMeta {
    /// Partition id within its dataset.
    pub id: usize,
    /// Smallest key the partition holds.
    pub key_min: i64,
    /// Largest key the partition holds.
    pub key_max: i64,
    /// Valid row count.
    pub rows: usize,
    /// Key step within the partition; `None` if irregular or single-row.
    pub step: Option<i64>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn range_query_validates() {
        assert!(RangeQuery::new(5, 5).is_ok());
        assert!(RangeQuery::new(5, 4).is_err());
        assert_eq!(RangeQuery::new(1, 9).unwrap(), RangeQuery { lo: 1, hi: 9 });
    }

    #[test]
    fn slice_rows() {
        let s = PartitionSlice { partition: 0, row_start: 10, row_end: 25 };
        assert_eq!(s.rows(), 15);
    }

    #[test]
    fn zone_map_excludes_nans_from_bounds() {
        let z = ZoneMap::of(&[3.0, f32::NAN, -1.0, 7.5, f32::NAN]);
        assert_eq!(z.min, -1.0);
        assert_eq!(z.max, 7.5);
        assert_eq!(z.nans, 2);
        assert!(!z.is_empty());

        let all_nan = ZoneMap::of(&[f32::NAN, f32::NAN]);
        assert!(all_nan.is_empty());
        assert_eq!(all_nan.nans, 2);

        assert!(ZoneMap::of(&[]).is_empty());
    }

    #[test]
    fn zone_maps_of_covers_valid_rows_only() {
        let cols = vec![vec![1.0, 2.0, 99.0, 99.0], vec![5.0, f32::NAN, 99.0, 99.0]];
        let zs = zone_maps_of(&cols, 2);
        assert_eq!(zs.len(), 2);
        assert_eq!((zs[0].min, zs[0].max), (1.0, 2.0));
        assert_eq!((zs[1].min, zs[1].max), (5.0, 5.0));
        assert_eq!(zs[1].nans, 1);
    }

    #[test]
    fn predicate_matches_and_nan_never_does() {
        let p = ColumnPredicate { column: 0, op: PredOp::Gt, value: 30.0 };
        assert!(p.matches(30.5));
        assert!(!p.matches(30.0));
        assert!(!p.matches(f32::NAN));
        let p = ColumnPredicate { column: 0, op: PredOp::Le, value: 2.0 };
        assert!(p.matches(2.0));
        assert!(!p.matches(2.1));
        assert!(!p.matches(f32::NAN));
        assert_eq!(PredOp::Ge.symbol(), ">=");
    }

    #[test]
    fn predicate_satisfiable_against_zone_bounds() {
        let z = ZoneMap { min: 10.0, max: 20.0, nans: 3 };
        let pred = |op, value| ColumnPredicate { column: 0, op, value };
        assert!(pred(PredOp::Gt, 19.9).satisfiable(&z));
        assert!(!pred(PredOp::Gt, 20.0).satisfiable(&z));
        assert!(pred(PredOp::Ge, 20.0).satisfiable(&z));
        assert!(pred(PredOp::Lt, 10.1).satisfiable(&z));
        assert!(!pred(PredOp::Lt, 10.0).satisfiable(&z));
        assert!(pred(PredOp::Le, 10.0).satisfiable(&z));
        // An all-NaN partition satisfies no comparison: always prunable.
        let empty = ZoneMap::EMPTY;
        for op in [PredOp::Gt, PredOp::Ge, PredOp::Lt, PredOp::Le] {
            assert!(!pred(op, 0.0).satisfiable(&empty), "{op:?}");
        }
    }

    #[test]
    fn conjunction_helpers() {
        let preds = vec![
            ColumnPredicate { column: 0, op: PredOp::Gt, value: 1.0 },
            ColumnPredicate { column: 1, op: PredOp::Lt, value: 5.0 },
        ];
        let row = [2.0f32, 4.0];
        assert!(row_matches(&preds, |c| row[c]));
        let row = [2.0f32, 6.0];
        assert!(!row_matches(&preds, |c| row[c]));

        let zones = vec![
            ZoneMap { min: 0.0, max: 3.0, nans: 0 },
            ZoneMap { min: 4.0, max: 9.0, nans: 0 },
        ];
        assert!(zones_satisfiable(&preds, &zones));
        let blocked = vec![
            ZoneMap { min: 0.0, max: 1.0, nans: 0 }, // col0 > 1 impossible
            ZoneMap { min: 4.0, max: 9.0, nans: 0 },
        ];
        assert!(!zones_satisfiable(&preds, &blocked));
        // Empty conjunction never prunes, always matches.
        assert!(zones_satisfiable(&[], &zones));
        assert!(row_matches(&[], |_| 0.0));
    }
}
