//! Small numeric helpers shared by the bench harness and metrics:
//! robust summary statistics over timing samples, and the associative
//! moments algebra used to merge per-partition kernel partials.

/// Summary of a sample of f64 measurements (timings in seconds, bytes, ...).
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    /// Sample size.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Population standard deviation.
    pub std: f64,
    /// Smallest sample.
    pub min: f64,
    /// Median (nearest-rank).
    pub p50: f64,
    /// 95th percentile (nearest-rank).
    pub p95: f64,
    /// 99th percentile (nearest-rank).
    pub p99: f64,
    /// Largest sample.
    pub max: f64,
}

impl Summary {
    /// Compute a summary; returns `None` for an empty sample.
    pub fn of(samples: &[f64]) -> Option<Summary> {
        if samples.is_empty() {
            return None;
        }
        let mut v: Vec<f64> = samples.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        let n = v.len();
        let mean = v.iter().sum::<f64>() / n as f64;
        let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Some(Summary {
            n,
            mean,
            std: var.sqrt(),
            min: v[0],
            p50: percentile(&v, 0.50),
            p95: percentile(&v, 0.95),
            p99: percentile(&v, 0.99),
            max: v[n - 1],
        })
    }
}

/// Nearest-rank percentile of an already-sorted slice.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    debug_assert!(!sorted.is_empty());
    let idx = ((sorted.len() as f64 * q).ceil() as usize).clamp(1, sorted.len());
    sorted[idx - 1]
}

/// Associative raw-moment partial: the merge algebra for `segment_stats`
/// kernel outputs (DESIGN.md §3). `count == 0` is the identity element.
///
/// **NaN policy** (DESIGN.md §10): NaN values are *never* folded into
/// `max`/`min`/`sum`/`sumsq`/`count` — they are counted in `nans` instead,
/// so one corrupt reading cannot silently poison a whole period's mean and
/// standard deviation. `count` is therefore the number of *non-NaN* values.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Moments {
    /// Largest non-NaN value seen (kernel sentinel when empty).
    pub max: f32,
    /// Smallest non-NaN value seen (kernel sentinel when empty).
    pub min: f32,
    /// Sum of non-NaN values.
    pub sum: f64,
    /// Sum of squared non-NaN values.
    pub sumsq: f64,
    /// Number of non-NaN values folded in.
    pub count: f64,
    /// Number of NaN values encountered (excluded from everything above).
    pub nans: f64,
}

impl Moments {
    /// The identity (empty-range) partial, matching the kernel sentinels.
    pub const EMPTY: Moments = Moments {
        max: -3.4e38,
        min: 3.4e38,
        sum: 0.0,
        sumsq: 0.0,
        count: 0.0,
        nans: 0.0,
    };

    /// Build from the five f32 scalars a `segment_stats` execution returns.
    ///
    /// **Caveat:** the AOT kernels report no NaN count, so `nans` is 0
    /// here and a NaN in kernel input still folds into the sums on the
    /// HLO backend. The NaN policy is fully enforced by the native
    /// backend and the predicate-masked engine path (DESIGN.md §10 notes
    /// this as a known kernel-path limitation).
    pub fn from_kernel(max: f32, min: f32, sum: f32, sumsq: f32, count: f32) -> Moments {
        Moments {
            max,
            min,
            sum: sum as f64,
            sumsq: sumsq as f64,
            count: count as f64,
            nans: 0.0,
        }
    }

    /// Single-pass scan of a raw slice (the Native backend / test oracle).
    pub fn scan(xs: &[f32]) -> Moments {
        let mut m = Moments::EMPTY;
        for &x in xs {
            m.absorb(x);
        }
        m
    }

    /// Fold one value in (NaN is counted, not folded).
    pub fn absorb(&mut self, x: f32) {
        if x.is_nan() {
            self.nans += 1.0;
            return;
        }
        self.max = self.max.max(x);
        self.min = self.min.min(x);
        self.sum += x as f64;
        self.sumsq += (x as f64) * (x as f64);
        self.count += 1.0;
    }

    /// Associative merge of two partials.
    pub fn merge(self, other: Moments) -> Moments {
        Moments {
            max: self.max.max(other.max),
            min: self.min.min(other.min),
            sum: self.sum + other.sum,
            sumsq: self.sumsq + other.sumsq,
            count: self.count + other.count,
            nans: self.nans + other.nans,
        }
    }

    /// Whether no value has been folded in.
    pub fn is_empty(&self) -> bool {
        self.count == 0.0
    }

    /// Arithmetic mean (NaN for an empty partial).
    pub fn mean(&self) -> f64 {
        self.sum / self.count
    }

    /// Population standard deviation (matches the paper's "standard
    /// deviation" statistic and `ref.py::finalize_stats`).
    ///
    /// The raw-moment variance `E[x²] − E[x]²` cancels catastrophically
    /// for large-magnitude data (sums ~1e16 differing in their last few
    /// ulps), so the variance is clamped at 0 before the square root —
    /// a merged partial can therefore never finalize to a NaN `std`.
    pub fn std(&self) -> f64 {
        let m = self.mean();
        (self.sumsq / self.count - m * m).max(0.0).sqrt()
    }
}

/// Number of independent accumulator lanes [`fold_stats_f32`] uses. Eight
/// f32 lanes break the serial add dependency so the scalar loop pipelines
/// (and autovectorizes to one SIMD register on SSE/NEON).
pub const FOLD_LANES: usize = 8;

/// The shared f32 statistics fold: max / min / sum / sum-of-squares over
/// the non-NaN values of `xs`, plus the NaN count.
///
/// This is **the** definition of a kernel-block partial: the native
/// backend's `segment_stats` and the seal-time aggregate sketches
/// ([`crate::index::ColumnSketch`]) both call it, so a sketch partial is
/// bit-identical to the partial a scan of the same rows would produce —
/// the invariant the aggregate-pushdown property tests assert.
///
/// Implementation: [`FOLD_LANES`] independent accumulators per pass
/// (combined in fixed lane order at the end, so the result is
/// deterministic), with branchless NaN handling — a NaN contributes 0 to
/// the sums, is invisible to max/min (IEEE `max(acc, NaN) == acc`), and
/// increments the NaN count.
pub fn fold_stats_f32(xs: &[f32]) -> (f32, f32, f32, f32, usize) {
    const NEG: f32 = -3.4e38;
    const POS: f32 = 3.4e38;
    let mut mx = [NEG; FOLD_LANES];
    let mut mn = [POS; FOLD_LANES];
    let mut sum = [0f32; FOLD_LANES];
    let mut sumsq = [0f32; FOLD_LANES];
    let mut nans = [0usize; FOLD_LANES];
    let mut chunks = xs.chunks_exact(FOLD_LANES);
    for chunk in &mut chunks {
        for (l, &x) in chunk.iter().enumerate() {
            let nan = x.is_nan();
            let v = if nan { 0.0 } else { x };
            // IEEE max/min return the non-NaN operand, so feeding the raw
            // value is safe and keeps the loop branch-free.
            mx[l] = mx[l].max(x);
            mn[l] = mn[l].min(x);
            sum[l] += v;
            sumsq[l] += v * v;
            nans[l] += nan as usize;
        }
    }
    for (l, &x) in chunks.remainder().iter().enumerate() {
        let nan = x.is_nan();
        let v = if nan { 0.0 } else { x };
        mx[l] = mx[l].max(x);
        mn[l] = mn[l].min(x);
        sum[l] += v;
        sumsq[l] += v * v;
        nans[l] += nan as usize;
    }
    // Fixed lane-order combine: deterministic for a given input slice.
    let mut out = (NEG, POS, 0f32, 0f32, 0usize);
    for l in 0..FOLD_LANES {
        out.0 = out.0.max(mx[l]);
        out.1 = out.1.min(mn[l]);
        out.2 += sum[l];
        out.3 += sumsq[l];
        out.4 += nans[l];
    }
    out
}

/// Predicate-masked variant of [`fold_stats_f32`]: fold only the rows of
/// `xs` whose `mask` entry is `true`, returning
/// `(max, min, sum, sumsq, selected, nans)` where `selected` is the
/// number of mask-true rows and `nans` the number of *selected* NaN
/// values (so `selected - nans` is the moments count). Rows with a
/// `false` mask are invisible: they contribute 0 to the sums, nothing to
/// max/min, and their NaN-ness is never counted.
///
/// The lane structure mirrors [`fold_stats_f32`] exactly — the same
/// [`FOLD_LANES`] accumulator arrays, the same branchless NaN handling
/// (a masked-in value feeds max/min raw, relying on IEEE
/// `max(acc, NaN) == acc`), and the same fixed lane-order combine — so
/// with an all-true mask the result is **bit-identical** to
/// [`fold_stats_f32`] over the same slice, and for any mask the result
/// is deterministic for a given `(xs, mask)` input.
///
/// Only `mask[..xs.len()]` is consulted; `mask` must be at least as long
/// as `xs`.
pub fn fold_stats_f32_masked(xs: &[f32], mask: &[bool]) -> (f32, f32, f32, f32, usize, usize) {
    const NEG: f32 = -3.4e38;
    const POS: f32 = 3.4e38;
    assert!(mask.len() >= xs.len(), "mask shorter than values");
    let mask = &mask[..xs.len()];
    let mut mx = [NEG; FOLD_LANES];
    let mut mn = [POS; FOLD_LANES];
    let mut sum = [0f32; FOLD_LANES];
    let mut sumsq = [0f32; FOLD_LANES];
    let mut sel = [0usize; FOLD_LANES];
    let mut nans = [0usize; FOLD_LANES];
    let mut chunks = xs.chunks_exact(FOLD_LANES);
    let mut mchunks = mask.chunks_exact(FOLD_LANES);
    for (chunk, mchunk) in (&mut chunks).zip(&mut mchunks) {
        for l in 0..FOLD_LANES {
            let x = chunk[l];
            let keep = mchunk[l];
            let nan = x.is_nan() & keep;
            // Per-lane select: a masked-out row degenerates to the lane's
            // identity values, so the loop stays branch-free.
            let v = if nan | !keep { 0.0 } else { x };
            let hi = if keep { x } else { NEG };
            let lo = if keep { x } else { POS };
            mx[l] = mx[l].max(hi);
            mn[l] = mn[l].min(lo);
            sum[l] += v;
            sumsq[l] += v * v;
            sel[l] += keep as usize;
            nans[l] += nan as usize;
        }
    }
    for (l, (&x, &keep)) in
        chunks.remainder().iter().zip(mchunks.remainder()).enumerate()
    {
        let nan = x.is_nan() & keep;
        let v = if nan | !keep { 0.0 } else { x };
        let hi = if keep { x } else { NEG };
        let lo = if keep { x } else { POS };
        mx[l] = mx[l].max(hi);
        mn[l] = mn[l].min(lo);
        sum[l] += v;
        sumsq[l] += v * v;
        sel[l] += keep as usize;
        nans[l] += nan as usize;
    }
    // Fixed lane-order combine: deterministic for a given (xs, mask).
    let mut out = (NEG, POS, 0f32, 0f32, 0usize, 0usize);
    for l in 0..FOLD_LANES {
        out.0 = out.0.max(mx[l]);
        out.1 = out.1.min(mn[l]);
        out.2 += sum[l];
        out.3 += sumsq[l];
        out.4 += sel[l];
        out.5 += nans[l];
    }
    out
}

/// Mergeable simple-linear-regression partial over (key, value) pairs:
/// everything a least-squares fit `value ≈ slope·key + intercept` needs,
/// carried in **centered co-moment** form (means + Σdx², Σdx·dy) rather
/// than raw power sums. The raw form (`n·Σx² − (Σx)²`) cancels
/// catastrophically for large-magnitude keys with a small spread —
/// epoch-millisecond timestamps spanning a minute would yield a pure-noise
/// denominator — while the centered co-moments stay conditioned on the
/// *spread*, not the magnitude. Partials merge with the standard pairwise
/// (Chan et al.) update, so per-partition partials computed at seal time
/// (the aggregate sketch) and partials scanned from raw edge rows compose
/// into the same fit (mathematically associative; f64 rounding may move
/// the last ulps when the merge tree regroups).
///
/// Same NaN policy as [`Moments`]: a NaN value is counted in `nans` and
/// excluded from the fit (keys are integers and cannot be NaN).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TrendPartial {
    /// Number of (key, value) pairs folded in (value non-NaN).
    pub n: f64,
    /// Mean key.
    pub mean_x: f64,
    /// Mean value.
    pub mean_y: f64,
    /// Centered key second moment Σ(x − mean_x)².
    pub sxx: f64,
    /// Centered co-moment Σ(x − mean_x)(y − mean_y).
    pub sxy: f64,
    /// Number of pairs excluded because their value was NaN.
    pub nans: f64,
}

impl TrendPartial {
    /// The identity (empty) partial.
    pub const EMPTY: TrendPartial =
        TrendPartial { n: 0.0, mean_x: 0.0, mean_y: 0.0, sxx: 0.0, sxy: 0.0, nans: 0.0 };

    /// Single-pass fold of parallel key/value slices (`keys.len()` pairs;
    /// `values` may be longer — padding is ignored).
    pub fn scan(keys: &[i64], values: &[f32]) -> TrendPartial {
        let mut t = TrendPartial::EMPTY;
        for (&k, &v) in keys.iter().zip(values) {
            t.absorb(k, v);
        }
        t
    }

    /// Fold one (key, value) pair in (NaN value is counted, not folded) —
    /// the Welford-style running update.
    pub fn absorb(&mut self, key: i64, value: f32) {
        if value.is_nan() {
            self.nans += 1.0;
            return;
        }
        let x = key as f64;
        let y = value as f64;
        self.n += 1.0;
        let dx = x - self.mean_x;
        self.mean_x += dx / self.n;
        let dy = y - self.mean_y;
        self.mean_y += dy / self.n;
        // Co-moment updates pair the pre-update x-delta with the
        // post-update means (the standard numerically stable form).
        self.sxy += dx * (y - self.mean_y);
        self.sxx += dx * (x - self.mean_x);
    }

    /// Merge two partials (pairwise co-moment combination). Merging with
    /// the empty partial is exact.
    pub fn merge(self, o: TrendPartial) -> TrendPartial {
        if self.n == 0.0 {
            return TrendPartial { nans: self.nans + o.nans, ..o };
        }
        if o.n == 0.0 {
            return TrendPartial { nans: self.nans + o.nans, ..self };
        }
        let n = self.n + o.n;
        let dx = o.mean_x - self.mean_x;
        let dy = o.mean_y - self.mean_y;
        let w = self.n * o.n / n;
        TrendPartial {
            n,
            mean_x: self.mean_x + dx * o.n / n,
            mean_y: self.mean_y + dy * o.n / n,
            sxx: self.sxx + o.sxx + dx * dx * w,
            sxy: self.sxy + o.sxy + dx * dy * w,
            nans: self.nans + o.nans,
        }
    }

    /// Whether no pair has been folded in.
    pub fn is_empty(&self) -> bool {
        self.n == 0.0
    }

    /// Least-squares slope, or `None` when fewer than two distinct keys
    /// were folded in (a vertical/degenerate fit).
    pub fn slope(&self) -> Option<f64> {
        if self.n < 2.0 || self.sxx <= 0.0 {
            return None;
        }
        Some(self.sxy / self.sxx)
    }

    /// Least-squares intercept (requires a defined [`Self::slope`]).
    pub fn intercept(&self) -> Option<f64> {
        self.slope().map(|b| self.mean_y - b * self.mean_x)
    }
}

/// Distance partial algebra for the `distance` kernel (l2 kept squared so
/// merging stays associative; take `.l2()` at the very end).
///
/// Same NaN policy as [`Moments`]: a pair whose difference is NaN (either
/// side NaN) is counted in `nans` and excluded from every distance.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DistancePartial {
    /// Sum of absolute differences.
    pub l1: f64,
    /// Sum of squared differences (kept squared so merging is associative).
    pub l2sq: f64,
    /// Largest absolute difference.
    pub linf: f32,
    /// Number of compared (non-NaN) pairs.
    pub count: f64,
    /// Number of pairs excluded because their difference was NaN.
    pub nans: f64,
}

impl DistancePartial {
    /// The identity (empty-range) partial.
    pub const EMPTY: DistancePartial =
        DistancePartial { l1: 0.0, l2sq: 0.0, linf: 0.0, count: 0.0, nans: 0.0 };

    /// Build from the four f32 scalars a `distance` kernel execution returns.
    pub fn from_kernel(l1: f32, l2sq: f32, linf: f32, count: f32) -> Self {
        DistancePartial {
            l1: l1 as f64,
            l2sq: l2sq as f64,
            linf,
            count: count as f64,
            nans: 0.0,
        }
    }

    /// Associative merge of two partials.
    pub fn merge(self, o: DistancePartial) -> DistancePartial {
        DistancePartial {
            l1: self.l1 + o.l1,
            l2sq: self.l2sq + o.l2sq,
            linf: self.linf.max(o.linf),
            count: self.count + o.count,
            nans: self.nans + o.nans,
        }
    }

    /// Finalized Euclidean distance.
    pub fn l2(&self) -> f64 {
        self.l2sq.sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant() {
        let s = Summary::of(&[2.0; 10]).unwrap();
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p50, 2.0);
        assert_eq!(s.min, s.max);
    }

    #[test]
    fn summary_percentiles_ordered() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs).unwrap();
        assert_eq!(s.p50, 50.0);
        assert_eq!(s.p95, 95.0);
        assert_eq!(s.p99, 99.0);
        assert!(s.min <= s.p50 && s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
    }

    #[test]
    fn summary_empty_is_none() {
        assert!(Summary::of(&[]).is_none());
    }

    #[test]
    fn moments_merge_equals_whole_scan() {
        let xs: Vec<f32> = (0..1000).map(|i| (i as f32).sin() * 10.0).collect();
        let whole = Moments::scan(&xs);
        for split in [1, 37, 500, 999] {
            let merged = Moments::scan(&xs[..split]).merge(Moments::scan(&xs[split..]));
            assert!((whole.sum - merged.sum).abs() < 1e-6);
            assert_eq!(whole.max, merged.max);
            assert_eq!(whole.min, merged.min);
            assert_eq!(whole.count, merged.count);
        }
    }

    #[test]
    fn moments_empty_is_identity() {
        let m = Moments::scan(&[1.0, 2.0, 3.0]);
        assert_eq!(m.merge(Moments::EMPTY), m);
        assert_eq!(Moments::EMPTY.merge(m), m);
        assert!(Moments::EMPTY.is_empty());
    }

    #[test]
    fn moments_mean_std_match_numpy_convention() {
        // x = [2, 4, 4, 4, 5, 5, 7, 9] — textbook example: mean 5, pop-std 2.
        let m = Moments::scan(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert_eq!(m.mean(), 5.0);
        assert!((m.std() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn distance_merge_associative() {
        let a = DistancePartial { l1: 1.0, l2sq: 2.0, linf: 0.5, count: 3.0, nans: 1.0 };
        let b = DistancePartial { l1: 2.0, l2sq: 1.0, linf: 0.9, count: 4.0, nans: 0.0 };
        let c = DistancePartial { l1: 0.5, l2sq: 0.25, linf: 1.5, count: 1.0, nans: 2.0 };
        assert_eq!(a.merge(b).merge(c), a.merge(b.merge(c)));
        assert_eq!(a.merge(DistancePartial::EMPTY), a);
    }

    #[test]
    fn distance_l2_is_sqrt() {
        let d = DistancePartial { l1: 0.0, l2sq: 9.0, linf: 0.0, count: 1.0, nans: 0.0 };
        assert_eq!(d.l2(), 3.0);
    }

    #[test]
    fn fold_stats_matches_sequential_on_integer_data() {
        // Integer-valued f32 data sums exactly in any association, so the
        // 8-lane fold must agree with a sequential oracle bit-for-bit.
        let xs: Vec<f32> = (0..1000).map(|i| ((i * 7) % 97) as f32).collect();
        for len in [0usize, 1, 7, 8, 9, 63, 64, 65, 1000] {
            let (mx, mn, sum, sumsq, nans) = fold_stats_f32(&xs[..len]);
            let mut want = Moments::EMPTY;
            for &x in &xs[..len] {
                want.absorb(x);
            }
            if len == 0 {
                assert_eq!(sum, 0.0);
                assert!(mx < -1e38 && mn > 1e38);
            } else {
                assert_eq!(mx, want.max, "len={len}");
                assert_eq!(mn, want.min, "len={len}");
                assert_eq!(sum as f64, want.sum, "len={len}");
                assert_eq!(sumsq as f64, want.sumsq, "len={len}");
            }
            assert_eq!(nans, 0);
        }
    }

    #[test]
    fn fold_stats_counts_nans_out() {
        let mut xs = vec![1.0f32; 100];
        xs[3] = f32::NAN;
        xs[64] = f32::NAN;
        xs[99] = 5.0;
        let (mx, mn, sum, sumsq, nans) = fold_stats_f32(&xs);
        assert_eq!(nans, 2);
        assert_eq!(mx, 5.0);
        assert_eq!(mn, 1.0);
        assert_eq!(sum, 97.0 + 5.0);
        assert_eq!(sumsq, 97.0 + 25.0);
        // All-NaN input: sentinels + full count.
        let (mx, mn, sum, _, nans) = fold_stats_f32(&[f32::NAN; 11]);
        assert!(mx < -1e38 && mn > 1e38);
        assert_eq!(sum, 0.0);
        assert_eq!(nans, 11);
    }

    #[test]
    fn masked_fold_all_true_is_bit_identical_to_unmasked() {
        // Awkward (non-exactly-summing) f32 data: the masked fold with an
        // all-true mask must reproduce fold_stats_f32 *bitwise*, since the
        // lane schedule is identical.
        let xs: Vec<f32> = (0..1000).map(|i| (i as f32).sin() * 1e3 + 0.1).collect();
        for len in [0usize, 1, 7, 8, 9, 63, 64, 65, 1000] {
            let mask = vec![true; len];
            let (mx, mn, sum, sumsq, sel, nans) =
                fold_stats_f32_masked(&xs[..len], &mask);
            let (wmx, wmn, wsum, wsumsq, wnans) = fold_stats_f32(&xs[..len]);
            assert_eq!(mx.to_bits(), wmx.to_bits(), "len={len}");
            assert_eq!(mn.to_bits(), wmn.to_bits(), "len={len}");
            assert_eq!(sum.to_bits(), wsum.to_bits(), "len={len}");
            assert_eq!(sumsq.to_bits(), wsumsq.to_bits(), "len={len}");
            assert_eq!(sel, len);
            assert_eq!(nans, wnans);
        }
    }

    #[test]
    fn masked_fold_matches_scan_oracle_on_selected_rows() {
        // Seeded pseudo-random mask over integer-valued data (sums are
        // exact in any association): the masked fold must agree with a
        // sequential absorb of exactly the selected rows.
        let xs: Vec<f32> = (0..777).map(|i| ((i * 13) % 251) as f32 - 40.0).collect();
        for (period, longer_mask) in [(2usize, false), (3, true), (7, false), (1, true)] {
            let mut mask: Vec<bool> = (0..xs.len()).map(|i| i % period == 0).collect();
            if longer_mask {
                mask.extend([true; 9]); // tail beyond xs must be ignored
            }
            let (mx, mn, sum, sumsq, sel, nans) = fold_stats_f32_masked(&xs, &mask);
            let mut want = Moments::EMPTY;
            for (i, &x) in xs.iter().enumerate() {
                if i % period == 0 {
                    want.absorb(x);
                }
            }
            assert_eq!(mx, want.max, "period={period}");
            assert_eq!(mn, want.min, "period={period}");
            assert_eq!(sum as f64, want.sum, "period={period}");
            assert_eq!(sumsq as f64, want.sumsq, "period={period}");
            assert_eq!(sel, xs.len().div_ceil(period));
            assert_eq!(nans, 0);
        }
    }

    #[test]
    fn masked_fold_nan_policy_and_edge_masks() {
        // Selected NaNs are counted; deselected NaNs are invisible.
        let mut xs = vec![2.0f32; 40];
        xs[5] = f32::NAN; // selected below
        xs[6] = f32::NAN; // masked out below
        xs[39] = 7.0;
        let mut mask = vec![true; 40];
        mask[6] = false;
        mask[0] = false; // a masked-out ordinary value
        let (mx, mn, sum, sumsq, sel, nans) = fold_stats_f32_masked(&xs, &mask);
        assert_eq!(nans, 1, "only the selected NaN counts");
        assert_eq!(sel, 38);
        assert_eq!(mx, 7.0);
        assert_eq!(mn, 2.0);
        assert_eq!(sum, 36.0 * 2.0 + 7.0);
        assert_eq!(sumsq, 36.0 * 4.0 + 49.0);

        // All-false mask: the identity partial regardless of the data.
        let (mx, mn, sum, sumsq, sel, nans) =
            fold_stats_f32_masked(&xs, &vec![false; 40]);
        assert!(mx < -1e38 && mn > 1e38);
        assert_eq!((sum, sumsq, sel, nans), (0.0, 0.0, 0, 0));

        // Empty input.
        let (_, _, sum, _, sel, nans) = fold_stats_f32_masked(&[], &[]);
        assert_eq!((sum, sel, nans), (0.0, 0, 0));

        // Deterministic: repeated runs produce the same bits.
        let a = fold_stats_f32_masked(&xs, &mask);
        let b = fold_stats_f32_masked(&xs, &mask);
        assert_eq!(a.2.to_bits(), b.2.to_bits());
    }

    #[test]
    #[should_panic(expected = "mask shorter")]
    fn masked_fold_rejects_short_mask() {
        fold_stats_f32_masked(&[1.0, 2.0], &[true]);
    }

    #[test]
    fn trend_partial_merge_matches_whole_scan() {
        let keys: Vec<i64> = (0..500).map(|i| i * 10).collect();
        let vals: Vec<f32> = keys.iter().map(|&k| 3.0 + 0.25 * k as f32).collect();
        let whole = TrendPartial::scan(&keys, &vals);
        assert!((whole.slope().unwrap() - 0.25).abs() < 1e-9);
        assert!((whole.intercept().unwrap() - 3.0).abs() < 1e-6);
        for split in [1usize, 100, 499] {
            let merged = TrendPartial::scan(&keys[..split], &vals[..split])
                .merge(TrendPartial::scan(&keys[split..], &vals[split..]));
            // Pairwise merge regroups the f64 arithmetic, so compare the
            // fit (and the exact counts), not the partial bit patterns.
            assert_eq!(merged.n, whole.n, "split={split}");
            assert_eq!(merged.nans, whole.nans);
            assert!((merged.mean_x - whole.mean_x).abs() < 1e-9, "split={split}");
            assert!(
                (merged.slope().unwrap() - whole.slope().unwrap()).abs() < 1e-9,
                "split={split}"
            );
            assert!((merged.intercept().unwrap() - 3.0).abs() < 1e-6);
        }
        // The empty partial is an exact identity on both sides.
        assert_eq!(whole.merge(TrendPartial::EMPTY), whole);
        assert_eq!(TrendPartial::EMPTY.merge(whole), whole);
        assert!(TrendPartial::EMPTY.is_empty());
        assert!(TrendPartial::EMPTY.slope().is_none());
    }

    #[test]
    fn trend_partial_survives_large_magnitude_keys() {
        // Epoch-millisecond-scale keys spanning one minute: the raw-sum
        // normal equations (`n·Σx² − (Σx)²`) are pure rounding noise at
        // this magnitude; the centered co-moments must still recover the
        // fit to high relative accuracy.
        let base = 1_700_000_000_000i64;
        let keys: Vec<i64> = (0..60_000).map(|i| base + i).collect();
        let vals: Vec<f32> = (0..60_000).map(|i| 7.5 + 0.002 * i as f32).collect();
        let whole = TrendPartial::scan(&keys, &vals);
        let slope = whole.slope().expect("well-defined fit");
        assert!((slope - 0.002).abs() < 1e-6, "slope {slope}");
        // Predicted value at the middle key matches the data.
        let b = whole.intercept().unwrap();
        let mid = base + 30_000;
        let predicted = slope * mid as f64 + b;
        assert!((predicted - (7.5 + 0.002 * 30_000.0)).abs() < 0.05, "{predicted}");
        // Merged from uneven chunks: fit still agrees tightly.
        let merged = keys
            .chunks(7_001)
            .zip(vals.chunks(7_001))
            .map(|(k, v)| TrendPartial::scan(k, v))
            .fold(TrendPartial::EMPTY, TrendPartial::merge);
        assert!((merged.slope().unwrap() - slope).abs() < 1e-8);
    }

    #[test]
    fn trend_partial_nan_and_degenerate_cases() {
        let mut t = TrendPartial::EMPTY;
        t.absorb(1, 2.0);
        t.absorb(2, f32::NAN);
        t.absorb(3, 6.0);
        assert_eq!(t.n, 2.0);
        assert_eq!(t.nans, 1.0);
        assert!((t.slope().unwrap() - 2.0).abs() < 1e-12);
        // One point (or one repeated key) has no defined slope.
        let one = TrendPartial::scan(&[5], &[1.0]);
        assert!(one.slope().is_none() && one.intercept().is_none());
        let repeated = TrendPartial::scan(&[5, 5, 5], &[1.0, 2.0, 3.0]);
        assert!(repeated.slope().is_none());
    }

    #[test]
    fn merged_std_survives_catastrophic_cancellation() {
        // Numerical-stability stress (seeded): values at 1e8 scale make
        // `E[x²] − E[x]²` cancel in its last few ulps. Merged partials
        // must finalize to a *finite* std that matches a two-pass f64
        // oracle within a scale-relative tolerance — and a constant
        // series must clamp a tiny negative variance to exactly 0.
        let mut rng = crate::util::rng::Xoshiro256::seeded(0xA66);
        let scale = 1.0e8f32;
        let xs: Vec<f32> =
            (0..40_000).map(|_| scale + (rng.next_f32() - 0.5) * 1.0e3).collect();

        // Two-pass f64 oracle.
        let mean = xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64;
        let var =
            xs.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        let want_std = var.sqrt();
        assert!(want_std > 100.0, "noise must be visible: {want_std}");

        // f64 moments algebra, merged from uneven chunks.
        let merged = xs
            .chunks(977)
            .map(Moments::scan)
            .fold(Moments::EMPTY, Moments::merge);
        let got = merged.std();
        assert!(got.is_finite(), "merged std must never be NaN");
        assert!(
            (got - want_std).abs() < 0.05 * want_std,
            "merged {got} vs oracle {want_std}"
        );

        // Constant series: zero variance must finalize to exactly 0.
        let flat = vec![scale; 10_000];
        let m = flat.chunks(333).map(Moments::scan).fold(Moments::EMPTY, Moments::merge);
        assert_eq!(m.std(), 0.0);

        // Direct negative-variance partial (sums rounded against each
        // other, as large-scale merges produce): without the clamp this
        // square-roots a negative number into NaN.
        let hostile = Moments {
            max: 1.0,
            min: 1.0,
            sum: 3.000_000_000_000_000_4,
            sumsq: 2.999_999_999_999_999_6,
            count: 3.0,
            nans: 0.0,
        };
        assert!(hostile.sumsq / hostile.count < hostile.mean() * hostile.mean());
        assert_eq!(hostile.std(), 0.0, "negative variance must clamp, not NaN");

        // The f32 kernel-block fold at the same scale: far looser sums,
        // but the finalized std must still be finite (clamped, never NaN)
        // and bounded by a scale-relative error.
        let (mx, mn, sum, sumsq, _) = fold_stats_f32(&flat);
        let km = Moments::from_kernel(mx, mn, sum, sumsq, flat.len() as f32);
        assert!(km.std().is_finite());
        assert!(km.std() < 1e-2 * scale as f64, "kernel-fold std {}", km.std());
    }

    #[test]
    fn moments_nan_counted_not_poisoning() {
        // Regression: a single NaN used to poison sum/sumsq (mean and std
        // came out NaN) while count kept growing silently.
        let m = Moments::scan(&[1.0, f32::NAN, 3.0, f32::NAN]);
        assert_eq!(m.count, 2.0);
        assert_eq!(m.nans, 2.0);
        assert_eq!(m.max, 3.0);
        assert_eq!(m.min, 1.0);
        assert_eq!(m.mean(), 2.0);
        assert!(m.std().is_finite());
        // Merging carries the NaN count.
        let merged = m.merge(Moments::scan(&[f32::NAN]));
        assert_eq!(merged.nans, 3.0);
        assert_eq!(merged.count, 2.0);
        // All-NaN scan is the empty partial plus a count.
        let all = Moments::scan(&[f32::NAN; 4]);
        assert!(all.is_empty());
        assert_eq!(all.nans, 4.0);
    }
}
