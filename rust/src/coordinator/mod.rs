//! The leader: query planning, task routing/batching over the simulated
//! cluster, partial merging, and the interactive-session driver that
//! produces the paper's Fig 4 / Fig 6 measurements.

pub mod planner;
pub mod session;

pub use planner::{IndexKind, Method};
pub use session::{run_session, SessionReport};

use std::sync::Arc;

use crate::analysis::ops::slice_moments;
use crate::analysis::{Analyzer, PeriodStats};
use crate::cluster::{Cluster, NetworkModel};
use crate::config::AppConfig;
use crate::engine::{Dataset, OsebaContext};
use crate::error::{OsebaError, Result};
use crate::index::{Cias, ContentIndex, RangeQuery, TableIndex};
use crate::runtime::backend::AnalysisBackend;
use crate::storage::RecordBatch;
use crate::util::stats::Moments;

/// The driver/leader of the system.
pub struct Coordinator {
    ctx: OsebaContext,
    analyzer: Analyzer,
    backend: Arc<dyn AnalysisBackend>,
    cluster: Cluster,
    /// Batch all of a worker's kernel blocks into one backend submission.
    pub batch_kernel_calls: bool,
}

impl Coordinator {
    /// Build from config + an already-constructed backend.
    pub fn new(cfg: &AppConfig, backend: Arc<dyn AnalysisBackend>) -> Result<Coordinator> {
        let ctx = OsebaContext::new(cfg.ctx.clone());
        let cluster = Cluster::new(
            cfg.cluster_workers,
            0,
            NetworkModel { latency_us: cfg.net_latency_us },
        )?;
        Ok(Coordinator {
            ctx,
            analyzer: Analyzer::new(Arc::clone(&backend)),
            backend,
            cluster,
            batch_kernel_calls: true,
        })
    }

    pub fn context(&self) -> &OsebaContext {
        &self.ctx
    }

    pub fn analyzer(&self) -> &Analyzer {
        &self.analyzer
    }

    pub fn cluster(&self) -> &Cluster {
        &self.cluster
    }

    /// Load a batch as a cached dataset and register its partitions with
    /// the cluster placement.
    pub fn load(&self, batch: RecordBatch, num_partitions: usize) -> Result<Dataset> {
        let ds = self.ctx.load(batch, num_partitions)?;
        self.cluster.ensure_partitions(ds.num_partitions());
        Ok(ds)
    }

    /// Build the configured index over a dataset.
    pub fn build_index(&self, ds: &Dataset, kind: IndexKind) -> Result<Box<dyn ContentIndex>> {
        Ok(match kind {
            IndexKind::Table => Box::new(TableIndex::build(ds.partitions())?),
            IndexKind::Cias => Box::new(Cias::build(ds.partitions())?),
        })
    }

    /// **Baseline phase** (paper §IV-A "first method"): filter-scan all
    /// partitions, materialize + cache the selection, then analyze the
    /// filtered dataset. Returns the stats *and* the filtered dataset
    /// handle — which stays resident, exactly like Spark's default.
    pub fn analyze_period_default(
        &self,
        ds: &Dataset,
        q: RangeQuery,
        column: usize,
    ) -> Result<(PeriodStats, Dataset)> {
        let filtered = self.ctx.filter_range(ds, q)?;
        self.cluster.ensure_partitions(filtered.num_partitions());
        if filtered.total_rows() == 0 {
            return Err(OsebaError::InvalidRange(format!(
                "no rows in [{}, {}]",
                q.lo, q.hi
            )));
        }
        // Analyze every row of the filtered dataset, routed per worker.
        let slices: Vec<_> = filtered
            .partitions()
            .iter()
            .filter(|p| p.rows > 0)
            .map(|p| crate::index::PartitionSlice { partition: p.id, row_start: 0, row_end: p.rows })
            .collect();
        let owned: Vec<_> = slices
            .iter()
            .map(|s| (Arc::clone(&filtered.partitions()[s.partition]), *s))
            .collect();
        let stats = self.run_stats_tasks(owned, column)?;
        Ok((stats, filtered))
    }

    /// **Oseba phase** (paper §IV-A "second method"): index lookup targets
    /// the partitions + row ranges; per-worker tasks compute moments over
    /// zero-copy views of the *original* partitions; the leader merges.
    pub fn analyze_period_oseba(
        &self,
        ds: &Dataset,
        index: &dyn ContentIndex,
        q: RangeQuery,
        column: usize,
    ) -> Result<PeriodStats> {
        let slices = index.lookup(q);
        if slices.is_empty() {
            return Err(OsebaError::InvalidRange(format!(
                "no partitions intersect [{}, {}]",
                q.lo, q.hi
            )));
        }
        let owned = self.ctx.resolve_slices(ds, &slices, q);
        self.run_stats_tasks(owned, column)
    }

    /// Route owned slice tasks to workers, execute, merge, finalize.
    fn run_stats_tasks(
        &self,
        owned: Vec<(Arc<crate::storage::Partition>, crate::index::PartitionSlice)>,
        column: usize,
    ) -> Result<PeriodStats> {
        let by_slice: std::collections::HashMap<usize, Arc<crate::storage::Partition>> =
            owned.iter().map(|(p, s)| (s.partition, Arc::clone(p))).collect();
        let groups = self
            .cluster
            .route(&owned.iter().map(|(_, s)| *s).collect::<Vec<_>>())?;

        let batch = self.batch_kernel_calls;
        let net = self.cluster.net;
        let tasks: Vec<_> = groups
            .into_iter()
            .map(|(_w, slices)| {
                let backend = Arc::clone(&self.backend);
                let parts: Vec<_> = slices
                    .iter()
                    .map(|s| (Arc::clone(&by_slice[&s.partition]), *s))
                    .collect();
                move || -> Result<Moments> {
                    net.message(); // task dispatch to this worker
                    let mut m = Moments::EMPTY;
                    for (part, s) in &parts {
                        m = m.merge(slice_moments(
                            backend.as_ref(),
                            part,
                            s.row_start,
                            s.row_end,
                            column,
                            batch,
                        )?);
                    }
                    net.message(); // result return
                    Ok(m)
                }
            })
            .collect();

        let partials = self.ctx.pool().scope_execute(tasks);
        let mut merged = Moments::EMPTY;
        for p in partials {
            merged = merged.merge(p?);
        }
        PeriodStats::from_moments(merged)
            .ok_or_else(|| OsebaError::InvalidRange("empty selection".into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AppConfig, ContextConfig};
    use crate::datagen::ClimateGen;
    use crate::runtime::NativeBackend;

    fn coord(workers: usize) -> Coordinator {
        let cfg = AppConfig {
            ctx: ContextConfig { num_workers: 4, memory_budget: None },
            cluster_workers: workers,
            ..Default::default()
        };
        Coordinator::new(&cfg, Arc::new(NativeBackend)).unwrap()
    }

    fn q_hours(lo: i64, hi: i64) -> RangeQuery {
        RangeQuery { lo: lo * 3600, hi: hi * 3600 }
    }

    #[test]
    fn default_and_oseba_agree_exactly() {
        let c = coord(3);
        let ds = c.load(ClimateGen::default().generate(30_000), 15).unwrap();
        let index = c.build_index(&ds, IndexKind::Cias).unwrap();
        for (lo, hi) in [(0, 100), (5_000, 12_000), (29_000, 29_999), (100, 25_000)] {
            let q = q_hours(lo, hi);
            let (d, filtered) = c.analyze_period_default(&ds, q, 0).unwrap();
            let o = c.analyze_period_oseba(&ds, index.as_ref(), q, 0).unwrap();
            assert_eq!(d.count, o.count, "q={q:?}");
            assert_eq!(d.max, o.max);
            assert_eq!(d.min, o.min);
            assert!((d.mean - o.mean).abs() < 1e-6);
            assert!((d.std - o.std).abs() < 1e-6);
            c.context().unpersist(&filtered);
        }
    }

    #[test]
    fn oseba_touches_fewer_partitions() {
        let c = coord(2);
        let ds = c.load(ClimateGen::default().generate(30_000), 15).unwrap();
        let index = c.build_index(&ds, IndexKind::Cias).unwrap();
        let before = c.context().counters();
        let q = q_hours(0, 1_000); // first partition only
        c.analyze_period_oseba(&ds, index.as_ref(), q, 0).unwrap();
        let after = c.context().counters();
        assert_eq!(after.partitions_scanned, before.partitions_scanned);
        assert_eq!(after.partitions_targeted - before.partitions_targeted, 1);
    }

    #[test]
    fn default_grows_memory_oseba_does_not() {
        let c = coord(2);
        let ds = c.load(ClimateGen::default().generate(20_000), 10).unwrap();
        let index = c.build_index(&ds, IndexKind::Cias).unwrap();
        let base = c.context().memory_used();
        let q = q_hours(2_000, 9_000);
        c.analyze_period_oseba(&ds, index.as_ref(), q, 0).unwrap();
        assert_eq!(c.context().memory_used(), base);
        let (_, _filtered) = c.analyze_period_default(&ds, q, 0).unwrap();
        assert!(c.context().memory_used() > base);
    }

    #[test]
    fn survives_worker_failure() {
        let c = coord(4);
        let ds = c.load(ClimateGen::default().generate(20_000), 12).unwrap();
        let index = c.build_index(&ds, IndexKind::Cias).unwrap();
        let q = q_hours(1_000, 15_000);
        let before = c.analyze_period_oseba(&ds, index.as_ref(), q, 0).unwrap();
        c.cluster().kill_worker(2).unwrap();
        let after = c.analyze_period_oseba(&ds, index.as_ref(), q, 0).unwrap();
        assert_eq!(before.count, after.count);
        assert_eq!(before.max, after.max);
        assert!((before.mean - after.mean).abs() < 1e-9);
    }

    #[test]
    fn table_and_cias_agree_via_coordinator() {
        let c = coord(3);
        let ds = c.load(ClimateGen::default().generate(25_000), 9).unwrap();
        let t = c.build_index(&ds, IndexKind::Table).unwrap();
        let s = c.build_index(&ds, IndexKind::Cias).unwrap();
        let q = q_hours(3_000, 17_000);
        let a = c.analyze_period_oseba(&ds, t.as_ref(), q, 2).unwrap();
        let b = c.analyze_period_oseba(&ds, s.as_ref(), q, 2).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn miss_query_errors() {
        let c = coord(2);
        let ds = c.load(ClimateGen::default().generate(1_000), 4).unwrap();
        let index = c.build_index(&ds, IndexKind::Cias).unwrap();
        let q = RangeQuery { lo: i64::MAX - 5, hi: i64::MAX };
        assert!(c.analyze_period_oseba(&ds, index.as_ref(), q, 0).is_err());
        assert!(c.analyze_period_default(&ds, q, 0).is_err());
    }

    #[test]
    fn unbatched_matches_batched() {
        let mut c = coord(2);
        let ds = c.load(ClimateGen::default().generate(15_000), 6).unwrap();
        let index = c.build_index(&ds, IndexKind::Cias).unwrap();
        let q = q_hours(500, 11_000);
        let a = c.analyze_period_oseba(&ds, index.as_ref(), q, 0).unwrap();
        c.batch_kernel_calls = false;
        let b = c.analyze_period_oseba(&ds, index.as_ref(), q, 0).unwrap();
        assert_eq!(a, b);
    }
}
