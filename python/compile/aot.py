"""AOT lowering: L2 graphs → HLO text artifacts + manifest.

Run once at build time (``make artifacts``); the rust runtime then loads
``artifacts/<name>.hlo.txt`` via ``HloModuleProto::from_text_file`` and
compiles each on the PJRT CPU client.

Interchange is HLO **text**, not a serialized ``HloModuleProto``: jax ≥ 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Every entry is lowered with ``return_tuple=True`` so the rust side unwraps a
single tuple literal regardless of arity.
"""

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_desc(spec) -> dict:
    return {"shape": list(spec.shape), "dtype": str(spec.dtype)}


def _result_desc(fn, example_args) -> list:
    out = jax.eval_shape(fn, *example_args)
    leaves = jax.tree_util.tree_leaves(out)
    return [_spec_desc(s) for s in leaves]


def source_fingerprint() -> str:
    """Hash of the compile package sources — drives `make artifacts` no-op."""
    pkg = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for root, _, files in sorted(os.walk(pkg)):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()[:16]


def build(outdir: str) -> dict:
    os.makedirs(outdir, exist_ok=True)
    manifest = {
        "block_rows": model.BLOCK_ROWS,
        "hist_bins": model.HIST_BINS,
        "ma_windows": list(model.MA_WINDOWS),
        "fingerprint": source_fingerprint(),
        "entries": {},
    }
    for name, (fn, example_args) in model.entries().items():
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        path = os.path.join(outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["entries"][name] = {
            "file": f"{name}.hlo.txt",
            "params": [_spec_desc(s) for s in example_args],
            "results": _result_desc(fn, example_args),
        }
        print(f"  lowered {name:<24} -> {path} ({len(text)} chars)")
    mpath = os.path.join(outdir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"  wrote {mpath} ({len(manifest['entries'])} entries)")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifact output directory")
    args = ap.parse_args()
    build(args.out)


if __name__ == "__main__":
    main()
