//! The store manifest: a JSON document (written with the in-tree
//! [`crate::util::json`]) that describes a segment directory — schema,
//! per-segment metadata, and a snapshot of the super index (the CIAS
//! compressed tuple + associated search list) so [`super::TieredStore::open`]
//! restores lookup in O(index size) without reading any segment data.
//!
//! The segment list doubles as the §III-A table index: each entry is
//! exactly one [`PartitionMeta`], so a table-index caller can rebuild from
//! the same manifest.
//!
//! Keys are persisted as JSON numbers; magnitudes beyond 2^53 would lose
//! precision and are rejected at save time.

use std::path::Path;
use std::sync::Arc;

use crate::error::{OsebaError, Result};
use crate::index::{
    BlockSketches, Cias, ColumnSketch, MembershipFilter, PartitionMeta, ZoneMap,
};
use crate::storage::Schema;
use crate::store::crc32::crc32;
use crate::store::fault::{site, StoreIo};
use crate::util::json::Json;
use crate::util::stats::{Moments, TrendPartial};

/// Manifest file name inside a store directory.
pub const MANIFEST_FILE: &str = "manifest.json";
/// Durable copy of the previous manifest, written by `save` before each
/// commit — the rollback snapshot open-time recovery restores when
/// `manifest.json` itself is torn or corrupt.
pub const PREV_MANIFEST_FILE: &str = "manifest.json.prev";
/// `format` field value identifying a store manifest.
pub const FORMAT: &str = "oseba-store";
/// Current manifest version. Version 2 added per-segment `zones` (the
/// per-column value-domain zone maps the query planner prunes by);
/// version 3 added per-segment `sketch` — the per-column aggregate
/// sketches (moments + trend partials) the planner answers fully-covered
/// partitions from without faulting them in; version 4 added per-segment
/// `filter` — the per-column membership filters (hex-encoded with their
/// own CRC-32) the planner prunes equality predicates by before
/// fault-in; version 5 adds per-segment `blocks` — the per-block sketch
/// hierarchy (the binary [`BlockSketches`] codec, hex-encoded with its
/// own CRC-32) the executor classifies kernel blocks of cold partitions
/// by before fault-in (DESIGN.md §15). Older manifests are still
/// readable: v1 zones default to the unbounded sentinel (never prunes),
/// pre-v3 sketches default to the "no sketch → always scan" sentinel
/// (`None`), pre-v4 filters default to the "no filter → always consider"
/// sentinel (`None`), and pre-v5 blocks default to the "no block
/// sketches → scan every targeted block" sentinel (`None`); `save`
/// rewrites at the current version with real metadata.
pub const VERSION: usize = 5;
/// Oldest manifest version `open` still accepts.
pub const MIN_VERSION: usize = 1;

/// One segment's manifest entry.
#[derive(Clone, Debug, PartialEq)]
pub struct SegmentEntry {
    /// Segment file name, relative to the store directory.
    pub file: String,
    /// The partition metadata (also a table-index row).
    pub meta: PartitionMeta,
    /// Per-column zone maps (one per schema value column), so cold
    /// partitions can be zone-pruned before any fault-in.
    pub zones: Vec<ZoneMap>,
    /// Per-column aggregate sketches (one per schema value column), so
    /// fully-covered cold partitions are answered with zero fault-in.
    /// `None` for pre-v3 manifests, or when a sketch holds a non-finite
    /// sum JSON cannot carry — both mean "always scan", never wrong.
    pub sketches: Option<Vec<ColumnSketch>>,
    /// Per-column membership filters (one per schema value column), so
    /// cold partitions are filter-pruned for equality predicates before
    /// any fault-in. `None` for pre-v4 manifests — "no filter → always
    /// consider", never wrong.
    pub filters: Option<Arc<Vec<MembershipFilter>>>,
    /// Per-block sketch hierarchy (every column, every kernel block), so
    /// cold partitions' blocks are classified — covered, pruned, or
    /// scanned — before any fault-in. `None` for pre-v5 manifests — "no
    /// block sketches → scan every targeted block", never wrong.
    pub blocks: Option<Arc<BlockSketches>>,
}

/// The parsed/serializable manifest.
#[derive(Clone, Debug)]
pub struct StoreManifest {
    /// Schema of every segment in the store.
    pub schema: Schema,
    /// Per-segment entries, in partition-id order.
    pub segments: Vec<SegmentEntry>,
    /// Super-index snapshot over the segments.
    pub index: Cias,
}

fn meta_to_json_map(m: &PartitionMeta) -> std::collections::BTreeMap<String, Json> {
    [
        ("id", Json::num(m.id as f64)),
        ("key_min", Json::num(m.key_min as f64)),
        ("key_max", Json::num(m.key_max as f64)),
        ("rows", Json::num(m.rows as f64)),
        ("step", m.step.map(|s| Json::num(s as f64)).unwrap_or(Json::Null)),
    ]
    .into_iter()
    .map(|(k, v)| (k.to_string(), v))
    .collect()
}

fn meta_to_json(m: &PartitionMeta) -> Json {
    Json::Obj(meta_to_json_map(m))
}

use crate::store::segment::MAX_ROWS;

fn meta_from_json(v: &Json) -> Result<PartitionMeta> {
    let as_i64 = |name: &str| -> Result<i64> {
        v.require(name)?
            .as_i64()
            .ok_or_else(|| OsebaError::Json(format!("segment field '{name}' must be an integer")))
    };
    let as_usize = |name: &str| -> Result<usize> {
        v.require(name)?.as_usize().ok_or_else(|| {
            OsebaError::Json(format!(
                "segment field '{name}' must be a non-negative integer"
            ))
        })
    };
    let step = match v.require("step")? {
        Json::Null => None,
        j => Some(j.as_i64().ok_or_else(|| {
            OsebaError::Json("segment field 'step' must be an integer or null".into())
        })?),
    };
    let rows = as_usize("rows")?;
    if rows == 0 || rows > MAX_ROWS {
        return Err(OsebaError::Store(format!(
            "segment row count {rows} out of range (1..={MAX_ROWS})"
        )));
    }
    Ok(PartitionMeta {
        id: as_usize("id")?,
        key_min: as_i64("key_min")?,
        key_max: as_i64("key_max")?,
        rows,
        step,
    })
}

fn key_fits(k: i64) -> bool {
    k.unsigned_abs() <= (1u64 << 53)
}

/// JSON rendering of one zone map. JSON has no NaN/Infinity, so an empty
/// zone (no non-NaN value) is written as `{"empty":true,...}` and a
/// non-finite bound degrades to `null` (parsed back as the unbounded
/// sentinel — pruning stays conservative).
fn zone_to_json(z: &ZoneMap) -> Json {
    if z.is_empty() {
        return Json::obj(vec![
            ("empty", Json::Bool(true)),
            ("nans", Json::num(z.nans as f64)),
        ]);
    }
    let bound = |v: f32| {
        if v.is_finite() {
            Json::num(v as f64)
        } else {
            Json::Null
        }
    };
    Json::obj(vec![
        ("min", bound(z.min)),
        ("max", bound(z.max)),
        ("nans", Json::num(z.nans as f64)),
    ])
}

fn zone_from_json(v: &Json) -> Result<ZoneMap> {
    let nans = v.require("nans")?.as_usize().ok_or_else(|| {
        OsebaError::Json("zone field 'nans' must be a non-negative integer".into())
    })?;
    if v.get("empty") == Some(&Json::Bool(true)) {
        return Ok(ZoneMap { nans, ..ZoneMap::EMPTY });
    }
    let bound = |name: &str, unbounded: f32| -> Result<f32> {
        match v.require(name)? {
            Json::Null => Ok(unbounded),
            j => j
                .as_f64()
                .map(|x| x as f32)
                .ok_or_else(|| OsebaError::Json(format!("zone field '{name}' must be a number"))),
        }
    };
    Ok(ZoneMap {
        min: bound("min", f32::NEG_INFINITY)?,
        max: bound("max", f32::INFINITY)?,
        nans,
    })
}

/// JSON rendering of one column's aggregate sketch. Every field of the
/// moments and trend partials is finite for real data (NaNs are counted
/// out of the sums by construction); a non-finite field (an `inf` data
/// value summed in) cannot survive JSON, so the caller degrades the whole
/// segment's sketch list to `null` instead — "no sketch → always scan".
fn sketch_to_json(s: &ColumnSketch) -> Json {
    let m = &s.moments;
    let t = &s.trend;
    Json::obj(vec![
        ("max", Json::num(m.max as f64)),
        ("min", Json::num(m.min as f64)),
        ("sum", Json::num(m.sum)),
        ("sumsq", Json::num(m.sumsq)),
        ("count", Json::num(m.count)),
        ("nans", Json::num(m.nans)),
        (
            "trend",
            Json::obj(vec![
                ("n", Json::num(t.n)),
                ("mx", Json::num(t.mean_x)),
                ("my", Json::num(t.mean_y)),
                ("sxx", Json::num(t.sxx)),
                ("sxy", Json::num(t.sxy)),
                ("nans", Json::num(t.nans)),
            ]),
        ),
    ])
}

/// Whether every numeric field of a sketch survives JSON (finite).
fn sketch_fits_json(s: &ColumnSketch) -> bool {
    let m = &s.moments;
    let t = &s.trend;
    [m.max as f64, m.min as f64, m.sum, m.sumsq, m.count, m.nans].iter().all(|v| v.is_finite())
        && [t.n, t.mean_x, t.mean_y, t.sxx, t.sxy, t.nans].iter().all(|v| v.is_finite())
}

fn to_hex(bytes: &[u8]) -> String {
    const HEX: &[u8; 16] = b"0123456789abcdef";
    let mut s = String::with_capacity(bytes.len() * 2);
    for &b in bytes {
        s.push(HEX[(b >> 4) as usize] as char);
        s.push(HEX[(b & 15) as usize] as char);
    }
    s
}

fn from_hex(s: &str) -> Result<Vec<u8>> {
    let nibble = |c: u8| -> Result<u8> {
        match c {
            b'0'..=b'9' => Ok(c - b'0'),
            b'a'..=b'f' => Ok(c - b'a' + 10),
            b'A'..=b'F' => Ok(c - b'A' + 10),
            _ => Err(OsebaError::Store(format!(
                "hex section holds a non-hex byte 0x{c:02x}"
            ))),
        }
    };
    let raw = s.as_bytes();
    if raw.len() % 2 != 0 {
        return Err(OsebaError::Store(format!(
            "hex section has odd length {}",
            raw.len()
        )));
    }
    raw.chunks_exact(2).map(|p| Ok(nibble(p[0])? << 4 | nibble(p[1])?)).collect()
}

/// Hex section of one column's membership filter: the filter codec bytes
/// prefixed with their own CRC-32 (little-endian), so a flipped character
/// anywhere in the section is rejected at open time.
fn filter_to_json(f: &MembershipFilter) -> Json {
    let payload = f.to_bytes();
    let mut framed = Vec::with_capacity(4 + payload.len());
    framed.extend_from_slice(&crc32(&payload).to_le_bytes());
    framed.extend_from_slice(&payload);
    Json::str(to_hex(&framed))
}

fn filter_from_json(v: &Json, segment: usize, column: usize) -> Result<MembershipFilter> {
    let hex = v.as_str().ok_or_else(|| {
        OsebaError::Store(format!(
            "segment {segment} filter column {column} must be a hex string"
        ))
    })?;
    let framed = from_hex(hex)
        .map_err(|e| OsebaError::Store(format!("segment {segment} filter column {column}: {e}")))?;
    if framed.len() < 4 {
        return Err(OsebaError::Store(format!(
            "segment {segment} filter column {column} truncated ({} bytes)",
            framed.len()
        )));
    }
    let stored = u32::from_le_bytes([framed[0], framed[1], framed[2], framed[3]]);
    let payload = &framed[4..];
    let computed = crc32(payload);
    if stored != computed {
        return Err(OsebaError::Store(format!(
            "segment {segment} filter column {column} crc mismatch \
             (stored {stored:08x}, computed {computed:08x})"
        )));
    }
    MembershipFilter::from_bytes(payload)
        .map_err(|e| OsebaError::Store(format!("segment {segment} filter column {column}: {e}")))
}

/// Hex section of one segment's block-sketch hierarchy: the binary
/// [`BlockSketches`] codec bytes prefixed with their own CRC-32
/// (little-endian), mirroring the filter section's framing — a flipped
/// character anywhere in the section is rejected at open time. Binary,
/// so non-finite partials (an `inf` data value summed into a block)
/// round-trip exactly; unlike the sketch section there is no forced
/// `null` degradation.
fn blocks_to_json(b: &BlockSketches) -> Json {
    let payload = b.to_bytes();
    let mut framed = Vec::with_capacity(4 + payload.len());
    framed.extend_from_slice(&crc32(&payload).to_le_bytes());
    framed.extend_from_slice(&payload);
    Json::str(to_hex(&framed))
}

fn blocks_from_json(v: &Json, segment: usize) -> Result<BlockSketches> {
    let hex = v.as_str().ok_or_else(|| {
        OsebaError::Store(format!(
            "segment {segment} blocks section must be a hex string"
        ))
    })?;
    let framed = from_hex(hex)
        .map_err(|e| OsebaError::Store(format!("segment {segment} blocks section: {e}")))?;
    if framed.len() < 4 {
        return Err(OsebaError::Store(format!(
            "segment {segment} blocks section truncated ({} bytes)",
            framed.len()
        )));
    }
    let stored = u32::from_le_bytes([framed[0], framed[1], framed[2], framed[3]]);
    let payload = &framed[4..];
    let computed = crc32(payload);
    if stored != computed {
        return Err(OsebaError::Store(format!(
            "segment {segment} blocks section crc mismatch \
             (stored {stored:08x}, computed {computed:08x})"
        )));
    }
    BlockSketches::from_bytes(payload)
        .map_err(|e| OsebaError::Store(format!("segment {segment} blocks section: {e}")))
}

fn sketch_from_json(v: &Json) -> Result<ColumnSketch> {
    let num = |obj: &Json, name: &str| -> Result<f64> {
        obj.require(name)?.as_f64().ok_or_else(|| {
            OsebaError::Json(format!("sketch field '{name}' must be a number"))
        })
    };
    let t = v.require("trend")?;
    Ok(ColumnSketch {
        moments: Moments {
            max: num(v, "max")? as f32,
            min: num(v, "min")? as f32,
            sum: num(v, "sum")?,
            sumsq: num(v, "sumsq")?,
            count: num(v, "count")?,
            nans: num(v, "nans")?,
        },
        trend: TrendPartial {
            n: num(t, "n")?,
            mean_x: num(t, "mx")?,
            mean_y: num(t, "my")?,
            sxx: num(t, "sxx")?,
            sxy: num(t, "sxy")?,
            nans: num(t, "nans")?,
        },
    })
}

impl StoreManifest {
    /// Serialize. Fails if any key magnitude exceeds JSON-safe 2^53.
    pub fn to_json(&self) -> Result<Json> {
        for e in &self.segments {
            if !key_fits(e.meta.key_min) || !key_fits(e.meta.key_max) {
                return Err(OsebaError::Store(format!(
                    "segment {} keys exceed the manifest's 2^53 range",
                    e.meta.id
                )));
            }
        }
        let (base_key, step, rows_per_part, regular_parts, asl) = self.index.components();
        Ok(Json::obj(vec![
            ("format", Json::str(FORMAT)),
            ("version", Json::num(VERSION as f64)),
            (
                "schema",
                Json::obj(vec![
                    ("key", Json::str(self.schema.key.clone())),
                    (
                        "columns",
                        Json::arr(self.schema.columns.iter().map(|c| Json::str(c.clone())).collect()),
                    ),
                ]),
            ),
            (
                "segments",
                Json::arr(
                    self.segments
                        .iter()
                        .map(|e| {
                            let mut obj = meta_to_json_map(&e.meta);
                            obj.insert("file".into(), Json::str(e.file.clone()));
                            obj.insert(
                                "zones".into(),
                                Json::arr(e.zones.iter().map(zone_to_json).collect()),
                            );
                            let sketch = match &e.sketches {
                                Some(sks) if sks.iter().all(sketch_fits_json) => {
                                    Json::arr(sks.iter().map(sketch_to_json).collect())
                                }
                                _ => Json::Null,
                            };
                            obj.insert("sketch".into(), sketch);
                            let filter = match &e.filters {
                                Some(fs) => {
                                    Json::arr(fs.iter().map(filter_to_json).collect())
                                }
                                None => Json::Null,
                            };
                            obj.insert("filter".into(), filter);
                            let blocks = match &e.blocks {
                                Some(b) => blocks_to_json(b),
                                None => Json::Null,
                            };
                            obj.insert("blocks".into(), blocks);
                            Json::Obj(obj)
                        })
                        .collect(),
                ),
            ),
            (
                "index",
                Json::obj(vec![
                    ("kind", Json::str("cias")),
                    ("base_key", Json::num(base_key as f64)),
                    ("step", Json::num(step as f64)),
                    ("rows_per_part", Json::num(rows_per_part as f64)),
                    ("regular_parts", Json::num(regular_parts as f64)),
                    ("asl", Json::arr(asl.iter().map(meta_to_json).collect())),
                ]),
            ),
        ]))
    }

    /// Parse and validate a manifest document.
    pub fn from_json(v: &Json) -> Result<StoreManifest> {
        match v.require("format")?.as_str() {
            Some(FORMAT) => {}
            other => {
                return Err(OsebaError::Store(format!(
                    "not a store manifest (format {other:?}, want '{FORMAT}')"
                )))
            }
        }
        let version = match v.require("version")?.as_usize() {
            Some(n) if (MIN_VERSION..=VERSION).contains(&n) => n,
            other => {
                return Err(OsebaError::Store(format!(
                    "unsupported manifest version {other:?} \
                     (want {MIN_VERSION}..={VERSION})"
                )))
            }
        };

        let sv = v.require("schema")?;
        let key = sv
            .require("key")?
            .as_str()
            .ok_or_else(|| OsebaError::Json("schema key must be a string".into()))?;
        let cols = sv
            .require("columns")?
            .as_arr()
            .ok_or_else(|| OsebaError::Json("schema columns must be an array".into()))?;
        let col_names: Vec<&str> = cols
            .iter()
            .map(|c| {
                c.as_str()
                    .ok_or_else(|| OsebaError::Json("schema column must be a string".into()))
            })
            .collect::<Result<_>>()?;
        let schema = Schema::new(key, &col_names)?;

        let segs = v
            .require("segments")?
            .as_arr()
            .ok_or_else(|| OsebaError::Json("segments must be an array".into()))?;
        let mut segments = Vec::with_capacity(segs.len());
        for (i, s) in segs.iter().enumerate() {
            let meta = meta_from_json(s)?;
            if meta.id != i {
                return Err(OsebaError::Store(format!(
                    "segment list out of order: entry {i} has id {}",
                    meta.id
                )));
            }
            let file = s
                .require("file")?
                .as_str()
                .ok_or_else(|| OsebaError::Json("segment file must be a string".into()))?
                .to_string();
            // Segment files must be bare names inside the store directory
            // — a manifest must not be able to point reads elsewhere.
            if file.is_empty()
                || file.contains('/')
                || file.contains('\\')
                || file.starts_with("..")
            {
                return Err(OsebaError::Store(format!(
                    "segment file '{file}' is not a bare file name"
                )));
            }
            // v1 manifests predate zone maps: default every column to the
            // unbounded sentinel — never prunes, always correct.
            let zones = if version < 2 {
                vec![
                    ZoneMap { min: f32::NEG_INFINITY, max: f32::INFINITY, nans: 0 };
                    schema.width()
                ]
            } else {
                let zones = s
                    .require("zones")?
                    .as_arr()
                    .ok_or_else(|| OsebaError::Json("segment zones must be an array".into()))?
                    .iter()
                    .map(zone_from_json)
                    .collect::<Result<Vec<_>>>()?;
                if zones.len() != schema.width() {
                    return Err(OsebaError::Store(format!(
                        "segment {i} has {} zone maps for {} schema columns",
                        zones.len(),
                        schema.width()
                    )));
                }
                zones
            };
            // Pre-v3 manifests predate aggregate sketches: those segments
            // carry the "no sketch → always scan" sentinel. From v3 on the
            // field is mandatory (`null` allowed for non-finite sketches),
            // and a sketch list that disagrees with the schema's value
            // column count is rejected outright — a silent index mismatch
            // here would answer queries from the wrong column's sums.
            let sketches = if version < 3 {
                None
            } else {
                match s.require("sketch")? {
                    Json::Null => None,
                    Json::Arr(items) => {
                        if items.len() != schema.width() {
                            return Err(OsebaError::Store(format!(
                                "segment {i} has {} sketch columns for {} schema columns",
                                items.len(),
                                schema.width()
                            )));
                        }
                        Some(
                            items
                                .iter()
                                .map(sketch_from_json)
                                .collect::<Result<Vec<_>>>()?,
                        )
                    }
                    _ => {
                        return Err(OsebaError::Json(format!(
                            "segment {i}: 'sketch' must be an array or null"
                        )))
                    }
                }
            };
            // Pre-v4 manifests predate membership filters: those segments
            // carry the "no filter → always consider" sentinel. From v4 on
            // the field is mandatory (`null` = explicit opt-out), each
            // column's hex section is CRC-checked, and a filter list that
            // disagrees with the schema's value column count is rejected
            // outright — a misaligned filter would prune on the wrong
            // column's membership and silently drop rows.
            let filters = if version < 4 {
                None
            } else {
                match s.require("filter")? {
                    Json::Null => None,
                    Json::Arr(items) => {
                        if items.len() != schema.width() {
                            return Err(OsebaError::Store(format!(
                                "segment {i} has {} filter columns for {} schema columns",
                                items.len(),
                                schema.width()
                            )));
                        }
                        Some(Arc::new(
                            items
                                .iter()
                                .enumerate()
                                .map(|(ci, f)| filter_from_json(f, i, ci))
                                .collect::<Result<Vec<_>>>()?,
                        ))
                    }
                    _ => {
                        return Err(OsebaError::Store(format!(
                            "segment {i}: 'filter' must be an array or null"
                        )))
                    }
                }
            };
            // Pre-v5 manifests predate block sketches: those segments
            // carry the "no block sketches → scan every targeted block"
            // sentinel. From v5 on the field is mandatory (`null` =
            // explicit opt-out), the hex section is CRC-checked, and the
            // decoded hierarchy must agree with the schema's value column
            // count and the segment's row count — a misaligned hierarchy
            // would answer blocks from the wrong column's partials.
            let blocks = if version < 5 {
                None
            } else {
                match s.require("blocks")? {
                    Json::Null => None,
                    j => {
                        let b = blocks_from_json(j, i)?;
                        if b.num_columns() != schema.width() {
                            return Err(OsebaError::Store(format!(
                                "segment {i} has {} block-sketch columns for {} schema columns",
                                b.num_columns(),
                                schema.width()
                            )));
                        }
                        if b.num_blocks() != meta.rows.div_ceil(b.block_rows()) {
                            return Err(OsebaError::Store(format!(
                                "segment {i} has {} block sketches for {} rows at {} per block",
                                b.num_blocks(),
                                meta.rows,
                                b.block_rows()
                            )));
                        }
                        Some(Arc::new(b))
                    }
                }
            };
            segments.push(SegmentEntry { file, meta, zones, sketches, filters, blocks });
        }
        if segments.is_empty() {
            return Err(OsebaError::Store("manifest lists no segments".into()));
        }

        let iv = v.require("index")?;
        match iv.require("kind")?.as_str() {
            Some("cias") => {}
            other => {
                return Err(OsebaError::Store(format!("unknown index kind {other:?}")))
            }
        }
        let as_i64 = |name: &str| -> Result<i64> {
            iv.require(name)?
                .as_i64()
                .ok_or_else(|| OsebaError::Json(format!("index field '{name}' must be an integer")))
        };
        let as_usize = |name: &str| -> Result<usize> {
            iv.require(name)?.as_usize().ok_or_else(|| {
                OsebaError::Json(format!(
                    "index field '{name}' must be a non-negative integer"
                ))
            })
        };
        let asl = iv
            .require("asl")?
            .as_arr()
            .ok_or_else(|| OsebaError::Json("index asl must be an array".into()))?
            .iter()
            .map(meta_from_json)
            .collect::<Result<Vec<_>>>()?;
        let index = Cias::from_components(
            as_i64("base_key")?,
            as_i64("step")?,
            as_usize("rows_per_part")?,
            as_usize("regular_parts")?,
            asl,
        )?;
        if index.num_partitions() != segments.len() {
            return Err(OsebaError::Store(format!(
                "index covers {} partitions but manifest lists {} segments",
                index.num_partitions(),
                segments.len()
            )));
        }
        // The segment list is the ground truth (it is what `save` derived
        // the snapshot from); a snapshot that disagrees with it would
        // silently mis-target queries, so reject divergence outright.
        let rebuilt = Cias::from_meta(segments.iter().map(|e| e.meta).collect())?;
        if rebuilt.components() != index.components() {
            return Err(OsebaError::Store(
                "index snapshot disagrees with the segment list".into(),
            ));
        }

        Ok(StoreManifest { schema, segments, index })
    }

    /// Build a manifest for `segments`, deriving the index snapshot.
    pub fn for_segments(schema: Schema, segments: Vec<SegmentEntry>) -> Result<StoreManifest> {
        let index = Cias::from_meta(segments.iter().map(|e| e.meta).collect())?;
        Ok(StoreManifest { schema, segments, index })
    }

    /// Write to `<dir>/manifest.json` atomically and durably.
    pub fn save(&self, dir: impl AsRef<Path>) -> Result<()> {
        self.save_with(dir, &StoreIo::disabled())
    }

    /// [`StoreManifest::save`] through an explicit [`StoreIo`]. The commit
    /// protocol (DESIGN.md §16): fsync a copy of the previous manifest to
    /// `manifest.json.prev` (the rollback snapshot torn-manifest recovery
    /// restores), then durably write `manifest.json.tmp`, fsync it, rename
    /// it over `manifest.json`, and fsync the directory — a rename without
    /// those fsyncs can lose or tear the committed manifest on power loss.
    pub fn save_with(&self, dir: impl AsRef<Path>, io: &StoreIo) -> Result<()> {
        let path = dir.as_ref().join(MANIFEST_FILE);
        if io.exists(&path) {
            let prev_bytes = io.read(site::MANIFEST_WRITE, &path)?;
            let prev = dir.as_ref().join(PREV_MANIFEST_FILE);
            io.write_durable(site::MANIFEST_WRITE, &prev, &prev_bytes)?;
            io.sync_dir(site::MANIFEST_WRITE, dir.as_ref())?;
        }
        let bytes = self.to_json()?.to_string().into_bytes();
        io.commit(site::MANIFEST_WRITE, &path, &bytes)
    }

    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<StoreManifest> {
        Self::load_with(dir, &StoreIo::disabled())
    }

    /// [`StoreManifest::load`] through an explicit [`StoreIo`].
    pub fn load_with(dir: impl AsRef<Path>, io: &StoreIo) -> Result<StoreManifest> {
        let path = dir.as_ref().join(MANIFEST_FILE);
        let text = io.read_to_string(site::MANIFEST_READ, &path)?;
        Self::parse_named(&text, &path)
    }

    /// Parse + validate manifest `text`, naming `path` in errors — shared
    /// by [`StoreManifest::load_with`] and the open-time rollback path
    /// (which parses `manifest.json.prev` before trusting it).
    pub(crate) fn parse_named(text: &str, path: &Path) -> Result<StoreManifest> {
        let v = Json::parse(text)
            .map_err(|e| OsebaError::Store(format!("manifest '{}': {e}", path.display())))?;
        StoreManifest::from_json(&v)
            .map_err(|e| OsebaError::Store(format!("manifest '{}': {e}", path.display())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::{ContentIndex, RangeQuery};
    use crate::store::fault::FaultInjector;
    use crate::testing::temp_dir;

    /// A sketch with awkward (non-round) floats, to exercise exact JSON
    /// round-tripping of f64 sums.
    fn sample_sketch(salt: f64) -> ColumnSketch {
        ColumnSketch {
            moments: Moments {
                max: 42.125,
                min: -1.5,
                sum: 12345.678_901_234 + salt,
                sumsq: 9.876_543_210_123e7 + salt,
                count: 100.0,
                nans: 3.0,
            },
            trend: TrendPartial {
                n: 100.0,
                mean_x: 4.95e3 + salt,
                mean_y: 123.456_789_012_34,
                sxx: 8.3325e5 + salt / 3.0,
                sxy: 2.083e4 + salt,
                nans: 3.0,
            },
        }
    }

    /// A two-column, two-block hierarchy with awkward floats (rows = 100
    /// at 64 rows per block → 2 blocks per column).
    fn sample_blocks(salt: f64) -> Arc<BlockSketches> {
        let m = |s: f64| Moments {
            max: 42.125 + s as f32,
            min: -1.5,
            sum: 1234.567_890_123 + s,
            sumsq: 9.876_543_21e4 + s,
            count: 50.0,
            nans: 1.0,
        };
        Arc::new(BlockSketches::from_parts(
            64,
            vec![vec![m(salt), m(salt + 0.5)], vec![m(salt + 1.0), m(salt + 1.5)]],
        ))
    }

    fn sample(nparts: usize) -> StoreManifest {
        let rows = 100usize;
        let metas: Vec<PartitionMeta> = (0..nparts)
            .map(|i| PartitionMeta {
                id: i,
                key_min: (i * rows) as i64 * 10,
                key_max: ((i + 1) * rows - 1) as i64 * 10,
                rows,
                step: Some(10),
            })
            .collect();
        let index = Cias::from_meta(metas.clone()).unwrap();
        StoreManifest {
            schema: Schema::stock(),
            segments: metas
                .iter()
                .map(|m| SegmentEntry {
                    file: format!("part-{:05}.oseg", m.id),
                    meta: *m,
                    zones: vec![
                        ZoneMap { min: -1.5, max: 42.0, nans: 0 },
                        ZoneMap { min: 0.0, max: 9.0, nans: 3 },
                    ],
                    sketches: Some(vec![
                        sample_sketch(m.id as f64 / 7.0),
                        sample_sketch(m.id as f64 / 11.0),
                    ]),
                    filters: Some(Arc::new(vec![
                        MembershipFilter::build(&[1.25, -3.5, 42.0, m.id as f32]),
                        MembershipFilter::build(&[0.0, 7.75, m.id as f32 * 0.5]),
                    ])),
                    blocks: Some(sample_blocks(m.id as f64 / 13.0)),
                })
                .collect(),
            index,
        }
    }

    #[test]
    fn roundtrips_through_file() {
        let dir = temp_dir("manifest");
        let m = sample(6);
        m.save(&dir).unwrap();
        let back = StoreManifest::load(&dir).unwrap();
        assert_eq!(back.schema, m.schema);
        assert_eq!(back.segments, m.segments);
        let q = RangeQuery { lo: 150, hi: 3500 };
        assert_eq!(back.index.lookup(q), m.index.lookup(q));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn save_commits_durably_and_never_tears_the_manifest() {
        // Regression: `save` used to rename the staging file into place
        // without fsyncing it (or the directory), so a crash could leave
        // a torn `manifest.json`. The commit protocol now stages + fsyncs
        // + renames + syncs the directory, and copies the old manifest
        // durably to `.prev` first. Crash at every mutating op of the
        // commit: the loadable manifest on disk is always exactly the
        // old document or the new one.
        let dir = temp_dir("manifest-commit");
        let m = sample(3);
        m.save(&dir).unwrap();
        assert!(!dir.join(PREV_MANIFEST_FILE).exists(), "first save has no previous");
        let v1 = std::fs::read(dir.join(MANIFEST_FILE)).unwrap();

        // The second save copies the committed manifest to `.prev`.
        let m2 = sample(4);
        m2.save(&dir).unwrap();
        let v2 = std::fs::read(dir.join(MANIFEST_FILE)).unwrap();
        assert_ne!(v1, v2);
        assert_eq!(std::fs::read(dir.join(PREV_MANIFEST_FILE)).unwrap(), v1);

        let m3 = sample(5);
        let v3 = m3.to_json().unwrap().to_string().into_bytes();
        let inj = Arc::new(FaultInjector::new(11));
        let io = StoreIo::with(Arc::clone(&inj));
        let mut k = 0usize;
        loop {
            inj.arm_crash_after(k);
            match m3.save_with(&dir, &io) {
                Ok(()) => break,
                Err(e) => {
                    assert!(
                        matches!(e, OsebaError::Io { .. }),
                        "crash at op {k}: {e:?}"
                    );
                    let now = std::fs::read(dir.join(MANIFEST_FILE)).unwrap();
                    assert!(
                        now == v2 || now == v3,
                        "crash at op {k}: manifest is neither snapshot"
                    );
                    StoreManifest::load(&dir)
                        .unwrap_or_else(|e| panic!("crash at op {k}: torn manifest: {e}"));
                }
            }
            inj.disarm_crash();
            k += 1;
            assert!(k < 16, "commit battery did not converge");
        }
        assert!(k >= 4, "the commit must expose several crash points, saw {k}");
        assert_eq!(std::fs::read(dir.join(MANIFEST_FILE)).unwrap(), v3);
        // `.prev` holds whatever was committed when the successful
        // attempt began — v2, or v3 if a late crash already renamed the
        // new manifest into place.
        let prev = std::fs::read(dir.join(PREV_MANIFEST_FILE)).unwrap();
        assert!(prev == v2 || prev == v3, "`.prev` must be a committed snapshot");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_manifest_names_path() {
        let dir = temp_dir("manifest-miss");
        let err = StoreManifest::load(&dir).unwrap_err();
        assert!(err.to_string().contains("manifest.json"), "got: {err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rejects_tampered_documents() {
        let m = sample(3);
        let good = m.to_json().unwrap().to_string();
        // Wrong format marker.
        let bad = good.replace("oseba-store", "bogus");
        assert!(StoreManifest::from_json(&Json::parse(&bad).unwrap()).is_err());
        // Index/segments disagreement (count).
        let bad = good.replace("\"regular_parts\":3", "\"regular_parts\":2");
        assert!(StoreManifest::from_json(&Json::parse(&bad).unwrap()).is_err());
        // A self-consistent snapshot that diverges from the segment list
        // must also be rejected (it would silently mis-target queries).
        let bad = good.replace("\"base_key\":0", "\"base_key\":10");
        let err = StoreManifest::from_json(&Json::parse(&bad).unwrap()).unwrap_err();
        assert!(err.to_string().contains("disagrees"), "got: {err}");
        // Hostile numerics are clean errors, never panics.
        let bad = good.replace("\"rows\":100", "\"rows\":-1");
        assert!(StoreManifest::from_json(&Json::parse(&bad).unwrap()).is_err());
        let bad = good.replace("\"regular_parts\":3", "\"regular_parts\":-1");
        assert!(StoreManifest::from_json(&Json::parse(&bad).unwrap()).is_err());
        // A segment file must be a bare name — no path escapes.
        let bad = good.replace("part-00001.oseg", "../part-00001.oseg");
        assert!(StoreManifest::from_json(&Json::parse(&bad).unwrap()).is_err());
        // Not JSON at all.
        assert!(Json::parse("not json").is_err());
        // Zone-map count must match the schema width.
        let bad = good.replace(
            r#""zones":[{"#,
            r#""zones":[{"min":0,"max":1,"nans":0},{"#,
        );
        assert!(StoreManifest::from_json(&Json::parse(&bad).unwrap()).is_err());
    }

    /// Downgrade a serialized manifest to `version`, stripping the fields
    /// that version predates ("zones" < 2, "sketch" < 3, "filter" < 4,
    /// "blocks" < 5).
    fn downgrade(doc: &Json, version: usize) -> Json {
        let Json::Obj(mut top) = doc.clone() else { panic!("manifest is an object") };
        top.insert("version".into(), Json::num(version as f64));
        if let Some(Json::Arr(segs)) = top.get_mut("segments") {
            for s in segs {
                let Json::Obj(seg) = s else { panic!("segment is an object") };
                if version < 2 {
                    seg.remove("zones");
                }
                if version < 3 {
                    seg.remove("sketch");
                }
                if version < 4 {
                    seg.remove("filter");
                }
                if version < 5 {
                    seg.remove("blocks");
                }
            }
        }
        Json::Obj(top)
    }

    #[test]
    fn old_manifests_still_open_with_conservative_sentinels() {
        let doc = sample(2).to_json().unwrap();

        // v1 (no zones, no sketch, no filter): unbounded zones — never
        // prunes — and no sketches/filters — always scans, always
        // considers.
        let m = StoreManifest::from_json(&downgrade(&doc, 1)).unwrap();
        for e in &m.segments {
            assert_eq!(e.zones.len(), 2);
            for z in &e.zones {
                assert_eq!(z.min, f32::NEG_INFINITY);
                assert_eq!(z.max, f32::INFINITY);
                assert_eq!(z.nans, 0);
            }
            assert!(e.sketches.is_none(), "v1 has no sketches");
            assert!(e.filters.is_none(), "v1 has no filters");
            assert!(e.blocks.is_none(), "v1 has no block sketches");
        }

        // v2 (zones, no sketch): real zones survive, sketches absent.
        let m = StoreManifest::from_json(&downgrade(&doc, 2)).unwrap();
        for e in &m.segments {
            assert_eq!(e.zones[0].max, 42.0);
            assert!(e.sketches.is_none(), "v2 has no sketches");
            assert!(e.filters.is_none(), "v2 has no filters");
        }

        // v3 (zones + sketches, no filter): sketches survive, filters
        // default to the always-consider sentinel.
        let m = StoreManifest::from_json(&downgrade(&doc, 3)).unwrap();
        for e in &m.segments {
            assert!(e.sketches.is_some(), "v3 keeps sketches");
            assert!(e.filters.is_none(), "v3 has no filters");
            assert!(e.blocks.is_none(), "v3 has no block sketches");
        }

        // v4 (zones + sketches + filters, no blocks): filters survive,
        // block sketches default to the scan-every-block sentinel.
        let m = StoreManifest::from_json(&downgrade(&doc, 4)).unwrap();
        for e in &m.segments {
            assert!(e.filters.is_some(), "v4 keeps filters");
            assert!(e.blocks.is_none(), "v4 has no block sketches");
        }

        // Unknown future versions are still rejected.
        let good = doc.to_string();
        let v9 = good.replace("\"version\":5", "\"version\":9");
        assert!(StoreManifest::from_json(&Json::parse(&v9).unwrap()).is_err());
    }

    #[test]
    fn sketches_roundtrip_exactly_and_null_means_scan() {
        let m = sample(3);
        let back =
            StoreManifest::from_json(&Json::parse(&m.to_json().unwrap().to_string()).unwrap())
                .unwrap();
        // Bit-exact f64 round trip: the covered-partition answer after
        // open must equal the answer before save.
        assert_eq!(back.segments, m.segments);

        // A sketch with a non-finite sum degrades to null on write...
        let mut inf = sample(2);
        inf.segments[1].sketches.as_mut().unwrap()[0].moments.sum = f64::INFINITY;
        let text = inf.to_json().unwrap().to_string();
        let back = StoreManifest::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert!(back.segments[1].sketches.is_none(), "non-finite → no sketch");
        assert!(back.segments[0].sketches.is_some(), "other segments keep theirs");
    }

    #[test]
    fn sketch_width_mismatch_is_a_clear_store_error() {
        // A v3 manifest whose sketch list disagrees with the schema's
        // value-column count must be an explicit `OsebaError::Store`, not
        // a silent column-index mismatch at query time.
        let doc = sample(2).to_json().unwrap();
        let Json::Obj(mut top) = doc.clone() else { panic!() };
        if let Some(Json::Arr(segs)) = top.get_mut("segments") {
            let Json::Obj(seg) = &mut segs[0] else { panic!() };
            let Some(Json::Arr(sks)) = seg.get_mut("sketch") else { panic!() };
            sks.push(sks[0].clone()); // 3 sketch columns for a 2-column schema
        }
        let err = StoreManifest::from_json(&Json::Obj(top)).unwrap_err();
        assert!(matches!(err, OsebaError::Store(_)), "got: {err:?}");
        assert!(
            err.to_string().contains("sketch columns"),
            "error must name the mismatch, got: {err}"
        );

        // Wrong type for the sketch field is also a clean error.
        let bad = doc.to_string().replacen("\"sketch\":[", "\"sketch\":7,\"x\":[", 1);
        assert!(StoreManifest::from_json(&Json::parse(&bad).unwrap()).is_err());

        // A v3 manifest with the sketch field missing entirely is rejected
        // (the field is mandatory from v3 on; null is the opt-out).
        let m = StoreManifest::from_json(&downgrade(&doc, 3));
        assert!(m.is_ok(), "downgrade(3) keeps sketch — control arm");
        let Json::Obj(mut top) = doc else { panic!() };
        if let Some(Json::Arr(segs)) = top.get_mut("segments") {
            let Json::Obj(seg) = &mut segs[0] else { panic!() };
            seg.remove("sketch");
        }
        assert!(StoreManifest::from_json(&Json::Obj(top)).is_err());
    }

    #[test]
    fn filters_roundtrip_and_null_means_always_consider() {
        let m = sample(3);
        let back =
            StoreManifest::from_json(&Json::parse(&m.to_json().unwrap().to_string()).unwrap())
                .unwrap();
        // Bit-exact round trip: probes after open answer exactly as the
        // filters built at seal time would.
        assert_eq!(back.segments, m.segments);
        let fs = back.segments[1].filters.as_ref().unwrap();
        assert!(fs[0].contains(-3.5));
        assert!(fs[1].contains(7.75));

        // An explicit null filter field is the opt-out, not an error.
        let mut none = sample(2);
        none.segments[1].filters = None;
        let text = none.to_json().unwrap().to_string();
        let back = StoreManifest::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert!(back.segments[1].filters.is_none(), "null → always consider");
        assert!(back.segments[0].filters.is_some(), "other segments keep theirs");
    }

    #[test]
    fn filter_tampering_is_a_clear_store_error() {
        let doc = sample(2).to_json().unwrap();

        // Pull segment 0's first filter hex section out of the document.
        let hex_of = |doc: &Json| -> String {
            let segs = doc.get("segments").unwrap().as_arr().unwrap();
            let fs = segs[0].get("filter").unwrap().as_arr().unwrap();
            fs[0].as_str().unwrap().to_string()
        };
        let replace_hex = |doc: &Json, new_hex: &str| -> Json {
            let Json::Obj(mut top) = doc.clone() else { panic!() };
            if let Some(Json::Arr(segs)) = top.get_mut("segments") {
                let Json::Obj(seg) = &mut segs[0] else { panic!() };
                let Some(Json::Arr(fs)) = seg.get_mut("filter") else { panic!() };
                fs[0] = Json::str(new_hex.to_string());
            }
            Json::Obj(top)
        };
        let hex = hex_of(&doc);

        // Corrupt CRC: flip one hex digit of the payload (past the 8-char
        // CRC prefix) — the section's own CRC-32 must catch it.
        let mut chars: Vec<char> = hex.chars().collect();
        let at = 12;
        chars[at] = if chars[at] == '0' { '1' } else { '0' };
        let flipped: String = chars.iter().collect();
        let err =
            StoreManifest::from_json(&replace_hex(&doc, &flipped)).unwrap_err();
        assert!(matches!(err, OsebaError::Store(_)), "got: {err:?}");
        assert!(err.to_string().contains("crc"), "got: {err}");

        // Truncated filter bytes (valid hex, short payload).
        let err = StoreManifest::from_json(&replace_hex(&doc, &hex[..hex.len() - 16]))
            .unwrap_err();
        assert!(matches!(err, OsebaError::Store(_)), "got: {err:?}");

        // Odd hex length and non-hex characters are clean errors too.
        assert!(StoreManifest::from_json(&replace_hex(&doc, &hex[..hex.len() - 1])).is_err());
        assert!(StoreManifest::from_json(&replace_hex(&doc, "zz00")).is_err());

        // Filter-column-count mismatch: 3 filters for a 2-column schema.
        let Json::Obj(mut top) = doc.clone() else { panic!() };
        if let Some(Json::Arr(segs)) = top.get_mut("segments") {
            let Json::Obj(seg) = &mut segs[0] else { panic!() };
            let Some(Json::Arr(fs)) = seg.get_mut("filter") else { panic!() };
            fs.push(fs[0].clone());
        }
        let err = StoreManifest::from_json(&Json::Obj(top)).unwrap_err();
        assert!(matches!(err, OsebaError::Store(_)), "got: {err:?}");
        assert!(err.to_string().contains("filter columns"), "got: {err}");

        // Wrong type for the filter field is a clean error.
        let Json::Obj(mut top) = doc.clone() else { panic!() };
        if let Some(Json::Arr(segs)) = top.get_mut("segments") {
            let Json::Obj(seg) = &mut segs[0] else { panic!() };
            seg.insert("filter".into(), Json::num(7.0));
        }
        assert!(StoreManifest::from_json(&Json::Obj(top)).is_err());

        // A v4 manifest with the filter field missing entirely is rejected
        // (the field is mandatory from v4 on; null is the opt-out).
        let Json::Obj(mut top) = doc else { panic!() };
        if let Some(Json::Arr(segs)) = top.get_mut("segments") {
            let Json::Obj(seg) = &mut segs[0] else { panic!() };
            seg.remove("filter");
        }
        assert!(StoreManifest::from_json(&Json::Obj(top)).is_err());
    }

    #[test]
    fn block_sketch_tampering_is_a_clear_store_error() {
        let doc = sample(2).to_json().unwrap();

        // Non-finite partials survive the binary section exactly (no JSON
        // null degradation like the sketch list).
        let mut inf = sample(2);
        inf.segments[1].blocks = Some(Arc::new(BlockSketches::from_parts(
            64,
            vec![
                vec![Moments { sum: f64::INFINITY, ..Moments::EMPTY }, Moments::EMPTY],
                vec![Moments::EMPTY, Moments::EMPTY],
            ],
        )));
        let text = inf.to_json().unwrap().to_string();
        let back = StoreManifest::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.segments[1].blocks, inf.segments[1].blocks);

        let hex_of = |doc: &Json| -> String {
            let segs = doc.get("segments").unwrap().as_arr().unwrap();
            segs[0].get("blocks").unwrap().as_str().unwrap().to_string()
        };
        let replace_blocks = |doc: &Json, v: Json| -> Json {
            let Json::Obj(mut top) = doc.clone() else { panic!() };
            if let Some(Json::Arr(segs)) = top.get_mut("segments") {
                let Json::Obj(seg) = &mut segs[0] else { panic!() };
                seg.insert("blocks".into(), v);
            }
            Json::Obj(top)
        };
        let hex = hex_of(&doc);

        // Corrupt CRC: flip one hex digit of the payload (past the 8-char
        // CRC prefix) — the section's own CRC-32 must catch it.
        let mut chars: Vec<char> = hex.chars().collect();
        let at = 12;
        chars[at] = if chars[at] == '0' { '1' } else { '0' };
        let flipped: String = chars.iter().collect();
        let err = StoreManifest::from_json(&replace_blocks(&doc, Json::str(flipped)))
            .unwrap_err();
        assert!(matches!(err, OsebaError::Store(_)), "got: {err:?}");
        assert!(err.to_string().contains("crc"), "got: {err}");
        assert!(err.to_string().contains("blocks section"), "got: {err}");

        // Truncated payload (valid hex, even length), odd hex length,
        // non-hex characters, wrong JSON type: all clean errors.
        let short = Json::str(hex[..hex.len() - 16].to_string());
        assert!(StoreManifest::from_json(&replace_blocks(&doc, short)).is_err());
        let odd = Json::str(hex[..hex.len() - 1].to_string());
        assert!(StoreManifest::from_json(&replace_blocks(&doc, odd)).is_err());
        let junk = Json::str("zz00".to_string());
        assert!(StoreManifest::from_json(&replace_blocks(&doc, junk)).is_err());
        assert!(StoreManifest::from_json(&replace_blocks(&doc, Json::num(7.0))).is_err());

        // An explicit null is the opt-out, not an error.
        let back = StoreManifest::from_json(&replace_blocks(&doc, Json::Null)).unwrap();
        assert!(back.segments[0].blocks.is_none(), "null → scan every block");
        assert!(back.segments[1].blocks.is_some(), "other segments keep theirs");

        // Width mismatch: 3 block-sketch columns for a 2-column schema.
        let m = Moments::EMPTY;
        let mut wide = sample(2);
        wide.segments[0].blocks =
            Some(Arc::new(BlockSketches::from_parts(64, vec![vec![m; 2]; 3])));
        let err = StoreManifest::from_json(&wide.to_json().unwrap()).unwrap_err();
        assert!(err.to_string().contains("block-sketch columns"), "got: {err}");

        // Block-count/row-count mismatch: 1 block for 100 rows at 64/block.
        let mut stub = sample(2);
        stub.segments[0].blocks =
            Some(Arc::new(BlockSketches::from_parts(64, vec![vec![m; 1]; 2])));
        let err = StoreManifest::from_json(&stub.to_json().unwrap()).unwrap_err();
        assert!(err.to_string().contains("block sketches for"), "got: {err}");

        // A v5 manifest with the blocks field missing entirely is rejected
        // (the field is mandatory from v5 on; null is the opt-out).
        let Json::Obj(mut top) = doc else { panic!() };
        if let Some(Json::Arr(segs)) = top.get_mut("segments") {
            let Json::Obj(seg) = &mut segs[0] else { panic!() };
            seg.remove("blocks");
        }
        assert!(StoreManifest::from_json(&Json::Obj(top)).is_err());
    }

    #[test]
    fn zone_maps_roundtrip_including_empty() {
        let mut m = sample(2);
        // One all-NaN column (empty bounds) must survive the round trip.
        m.segments[1].zones[0] = ZoneMap { nans: 7, ..ZoneMap::EMPTY };
        let back = StoreManifest::from_json(&m.to_json().unwrap()).unwrap();
        assert_eq!(back.segments[0].zones, m.segments[0].zones);
        let z = &back.segments[1].zones[0];
        assert!(z.is_empty());
        assert_eq!(z.nans, 7);
        assert_eq!(back.segments[1].zones[1], m.segments[1].zones[1]);
    }
}
