//! Server anchor: surfaces `partitions_scanned` and `epoch` but not
//! `ghost_counter`.

pub fn info() -> String {
    let mut s = String::from("partitions_scanned");
    s.push_str("epoch");
    s
}
