//! Shared setup for the bench targets.

use std::sync::Arc;

use oseba::config::{AppConfig, BackendKind, ContextConfig};
use oseba::coordinator::Coordinator;
use oseba::datagen::ClimateGen;
use oseba::engine::Dataset;
use oseba::runtime::make_backend;
use oseba::util::json::Json;

/// Write a bench's machine-readable result document to
/// `BENCH_<name>.json` in the working directory (the perf-trajectory
/// artifact every paper-claim bench emits; CI uploads them).
#[allow(dead_code)]
pub fn write_bench_json(name: &str, doc: Json) {
    let out = format!("BENCH_{name}.json");
    std::fs::write(&out, doc.to_string()).unwrap_or_else(|e| panic!("write {out}: {e}"));
    println!("wrote {out}");
}

/// Artifacts presence → backend selection shared by all benches.
#[allow(dead_code)]
pub fn backend_kind() -> BackendKind {
    if std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.json")
        .exists()
    {
        BackendKind::Hlo
    } else {
        eprintln!("(artifacts not built; benches use the native backend)");
        BackendKind::Native
    }
}

#[allow(dead_code)]
pub fn app_cfg(backend: BackendKind) -> AppConfig {
    AppConfig {
        ctx: ContextConfig { num_workers: 4, memory_budget: None },
        cluster_workers: 4,
        backend,
        artifacts_dir: format!("{}/artifacts", env!("CARGO_MANIFEST_DIR")),
        ..Default::default()
    }
}

/// Fresh coordinator + loaded climate dataset of `bytes` raw size.
#[allow(dead_code)]
pub fn setup(bytes: usize, partitions: usize, backend: BackendKind) -> (Coordinator, Dataset, usize) {
    let cfg = app_cfg(backend);
    let be = make_backend(cfg.backend, &cfg.artifacts_dir).expect("backend");
    let coord = Coordinator::new(&cfg, be).expect("coordinator");
    let batch = ClimateGen::default().generate_bytes(bytes);
    let raw = batch.raw_bytes();
    let ds = coord.load(batch, partitions).expect("load");
    (coord, ds, raw)
}

/// Native-backend setup (for benches isolating L3 from kernel costs).
#[allow(dead_code)]
pub fn setup_native(bytes: usize, partitions: usize) -> (Coordinator, Dataset, usize) {
    setup(bytes, partitions, BackendKind::Native)
}

#[allow(dead_code)]
pub fn mib(b: usize) -> f64 {
    b as f64 / (1 << 20) as f64
}

#[allow(dead_code)]
pub fn make_coord(backend: BackendKind) -> Coordinator {
    let cfg = app_cfg(backend);
    let be = make_backend(cfg.backend, &cfg.artifacts_dir).expect("backend");
    Coordinator::new(&cfg, be).expect("coordinator")
}

#[allow(dead_code)]
pub fn arc_backend(backend: BackendKind) -> Arc<dyn oseba::runtime::AnalysisBackend> {
    let cfg = app_cfg(backend);
    make_backend(cfg.backend, &cfg.artifacts_dir).expect("backend")
}
