//! Minimal JSON value model, parser and writer.
//!
//! The vendored crate set has no `serde`/`serde_json`, so the crate carries
//! its own small JSON implementation. It is used for:
//!   * reading `artifacts/manifest.json` written by `python/compile/aot.py`;
//!   * the interactive server's line protocol;
//!   * metrics/bench result dumps consumed by EXPERIMENTS.md tooling.
//!
//! Scope: full JSON per RFC 8259 minus `\u` surrogate-pair edge handling
//! beyond the BMP (sufficient for our ASCII manifests; still validated).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{OsebaError, Result};

/// A JSON value. Objects use `BTreeMap` for deterministic serialization.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always an f64, as per the data model).
    Num(f64),
    /// A string.
    Str(String),
    /// An array of values.
    Arr(Vec<Json>),
    /// An object (sorted keys → deterministic output).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document (must consume all non-whitespace input).
    pub fn parse(input: &str) -> Result<Json> {
        let mut p = Parser { b: input.as_bytes(), i: 0 };
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(err(&p, "trailing characters"));
        }
        Ok(v)
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(xs) => {
                out.push('[');
                for (k, x) in xs.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (k, (key, val)) in m.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    write_escaped(key, out);
                    out.push(':');
                    val.write(out);
                }
                out.push('}');
            }
        }
    }

    // --- typed accessors (manifest reading) --------------------------------

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Field that must exist.
    pub fn require(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| OsebaError::Json(format!("missing field '{key}'")))
    }

    /// String value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Non-negative integral numeric value, if representable as `usize`.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| n.fract() == 0.0 && *n >= 0.0).map(|n| n as usize)
    }

    /// Integral numeric value, if representable as `i64`.
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().filter(|n| n.fract() == 0.0).map(|n| n as i64)
    }

    /// Element slice, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Field map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // --- builders (metrics dumps) ------------------------------------------

    /// Object from `(key, value)` pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Number value.
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    /// String value.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Array value.
    pub fn arr(xs: Vec<Json>) -> Json {
        Json::Arr(xs)
    }
}

/// Compact serialization (`value.to_string()` comes via `ToString`).
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

fn err(p: &Parser, msg: &str) -> OsebaError {
    OsebaError::Json(format!("{msg} at byte {}", p.i))
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(err(self, &format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(err(self, "invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(err(self, "unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(err(self, "expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            out.insert(key, self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(err(self, "expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(err(self, "unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| err(self, "bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(err(self, "bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| err(self, "bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| err(self, "bad \\u escape"))?;
                            self.i += 4;
                            s.push(char::from_u32(code)
                                .ok_or_else(|| err(self, "surrogate \\u escape unsupported"))?);
                        }
                        _ => return Err(err(self, "unknown escape")),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| err(self, "invalid utf-8"))?;
                    let c = match rest.chars().next() {
                        Some(c) => c,
                        None => return Err(err(self, "truncated utf-8")),
                    };
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.peek().is_some_and(|c| c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| err(self, "invalid number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| err(self, "invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse(r#""hi\n""#).unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} extra").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn roundtrips() {
        let cases = [
            r#"{"a":[1,2.5,"x"],"b":{"c":true,"d":null}}"#,
            r#"[["nested"],[],{}]"#,
            r#""esc \" \\ \n""#,
        ];
        for c in cases {
            let v = Json::parse(c).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2, "{c}");
        }
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
        assert_eq!(Json::parse("\"\u{e9}\"").unwrap(), Json::Str("é".into()));
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(4096.0).to_string(), "4096");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 42, "s": "x"}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize(), Some(42));
        assert_eq!(v.get("n").unwrap().as_i64(), Some(42));
        assert_eq!(v.get("s").unwrap().as_f64(), None);
        assert!(v.require("missing").is_err());
    }

    #[test]
    fn parses_real_manifest_shape() {
        let m = r#"{
          "block_rows": 4096,
          "entries": {
            "segment_stats": {
              "file": "segment_stats.hlo.txt",
              "params": [{"shape": [4096], "dtype": "float32"}],
              "results": [{"shape": [], "dtype": "float32"}]
            }
          }
        }"#;
        let v = Json::parse(m).unwrap();
        assert_eq!(v.require("block_rows").unwrap().as_usize(), Some(4096));
        let e = v.require("entries").unwrap().require("segment_stats").unwrap();
        assert_eq!(e.require("file").unwrap().as_str(), Some("segment_stats.hlo.txt"));
    }
}
