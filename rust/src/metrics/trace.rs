//! Per-query trace spans and the bounded slow-query log.
//!
//! A [`Span`] is one node of a query's phase tree: a name, a wall-clock
//! reading, the counts the planner's `Explain` computed for that phase,
//! and child spans. Spans are built *after* execution from already-
//! measured durations, so tracing adds no branches to the hot path.
//!
//! All wall-clock readings pass through [`sane_secs`]: the JSON a trace
//! emits can never contain a negative or non-finite duration, even if a
//! phase was zero-width or upstream clock arithmetic misbehaved.

use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::sync::MutexExt;

/// Clamp a wall-clock reading for serialization: negative, NaN, or
/// infinite readings (a zero-width phase rounded badly, or reordered
/// timestamps from another thread) become `0.0`.
pub fn sane_secs(secs: f64) -> f64 {
    if secs.is_finite() && secs > 0.0 {
        secs
    } else {
        0.0
    }
}

/// Fold the monotonic-safe elapsed time since `start` into `slot` and
/// return a fresh mark for the next phase (one clock read per phase
/// boundary). `saturating_duration_since` means a non-monotonic reading
/// can never underflow into a huge bogus duration.
pub fn phase_mark(slot: &mut Duration, start: Instant) -> Instant {
    let now = Instant::now();
    *slot += now.saturating_duration_since(start);
    now
}

/// One node of a per-query trace: a named phase with its wall time, the
/// plan counts attributed to it, and nested child phases.
#[derive(Clone, Debug, Default)]
pub struct Span {
    /// Phase name (`"query"`, `"targeting"`, `"zone_pruning"`, ...).
    pub name: &'static str,
    /// Wall-clock seconds spent in the phase.
    pub secs: f64,
    /// Phase-attributed counts, straight from the plan's `Explain`.
    pub counts: Vec<(&'static str, u64)>,
    /// Nested phases, in execution order.
    pub children: Vec<Span>,
}

impl Span {
    /// A new leaf span with zero duration and no counts.
    pub fn new(name: &'static str) -> Span {
        Span { name, secs: 0.0, counts: Vec::new(), children: Vec::new() }
    }

    /// Set the wall time (clamped through [`sane_secs`]).
    pub fn with_secs(mut self, secs: f64) -> Span {
        self.secs = sane_secs(secs);
        self
    }

    /// Attach one named count.
    pub fn count(mut self, key: &'static str, value: u64) -> Span {
        self.counts.push((key, value));
        self
    }

    /// Attach a child phase.
    pub fn child(mut self, child: Span) -> Span {
        self.children.push(child);
        self
    }

    /// JSON rendering: `name`/`secs`, each count inlined as its own key,
    /// and `children` (always present, possibly empty).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("name", Json::str(self.name)),
            ("secs", Json::num(sane_secs(self.secs))),
        ];
        for &(key, value) in &self.counts {
            fields.push((key, Json::num(value as f64)));
        }
        fields.push(("children", Json::arr(self.children.iter().map(Span::to_json).collect())));
        Json::obj(fields)
    }
}

/// Default capacity of the slow-query log: the N worst traces kept.
pub const SLOW_LOG_CAPACITY: usize = 8;

/// One retained slow query: how long it took, which op ran it, and the
/// full trace + explain for post-hoc diagnosis.
#[derive(Clone, Debug)]
pub struct SlowEntry {
    /// Wall-clock seconds for the whole request.
    pub secs: f64,
    /// Server op that ran the query (e.g. `"stats"`).
    pub op: &'static str,
    /// The query's span tree, serialized.
    pub trace: Json,
    /// The query's `explain` output, serialized.
    pub explain: Json,
}

/// Bounded in-memory log of the worst (slowest) queries seen.
///
/// `offer` keeps the `cap` entries with the largest `secs`: a new entry
/// replaces the current minimum only when it is slower, so the log
/// converges on the true worst set regardless of arrival order.
#[derive(Debug)]
pub struct SlowQueryLog {
    cap: usize,
    entries: Mutex<Vec<SlowEntry>>,
}

impl Default for SlowQueryLog {
    fn default() -> SlowQueryLog {
        SlowQueryLog::new(SLOW_LOG_CAPACITY)
    }
}

impl SlowQueryLog {
    /// An empty log retaining at most `cap` entries.
    pub fn new(cap: usize) -> SlowQueryLog {
        SlowQueryLog { cap, entries: Mutex::new(Vec::new()) }
    }

    /// Offer one finished query; it is retained iff it ranks among the
    /// `cap` slowest seen so far.
    pub fn offer(&self, entry: SlowEntry) {
        let mut entries = self.entries.lock_recover();
        if entries.len() < self.cap {
            entries.push(entry);
            return;
        }
        let min = entries
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.secs.total_cmp(&b.1.secs))
            .map(|(i, e)| (i, e.secs));
        if let Some((i, min_secs)) = min {
            if entry.secs > min_secs {
                entries[i] = entry;
            }
        }
    }

    /// Retained entries, slowest first.
    pub fn snapshot(&self) -> Vec<SlowEntry> {
        let mut entries = self.entries.lock_recover().clone();
        entries.sort_by(|a, b| b.secs.total_cmp(&a.secs));
        entries
    }

    /// JSON rendering: an array of `{secs, op, trace, explain}` objects,
    /// slowest first.
    pub fn to_json(&self) -> Json {
        Json::arr(
            self.snapshot()
                .into_iter()
                .map(|e| {
                    Json::obj(vec![
                        ("secs", Json::num(sane_secs(e.secs))),
                        ("op", Json::str(e.op)),
                        ("trace", e.trace),
                        ("explain", e.explain),
                    ])
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sane_secs_clamps_garbage() {
        assert_eq!(sane_secs(0.25), 0.25);
        assert_eq!(sane_secs(0.0), 0.0);
        assert_eq!(sane_secs(-1.0), 0.0);
        assert_eq!(sane_secs(f64::NAN), 0.0);
        assert_eq!(sane_secs(f64::INFINITY), 0.0);
        assert_eq!(sane_secs(f64::NEG_INFINITY), 0.0);
    }

    #[test]
    fn zero_width_span_serializes_to_zero() {
        // A forced zero-width phase: started and closed on the same
        // instant, then pushed through negative arithmetic upstream.
        let mut slot = Duration::ZERO;
        let start = Instant::now();
        phase_mark(&mut slot, start);
        let span = Span::new("targeting").with_secs(-slot.as_secs_f64()).count("considered", 0);
        let j = span.to_json().to_string();
        assert!(j.contains("\"secs\":0"), "negative/zero width must clamp to 0: {j}");
        assert!(j.contains("\"considered\":0"));
        assert!(j.contains("\"children\":[]"));
    }

    #[test]
    fn span_tree_round_trips_counts() {
        let span = Span::new("query")
            .with_secs(0.5)
            .count("partitions", 5)
            .child(Span::new("targeting").with_secs(0.1).count("considered", 7));
        let j = span.to_json();
        assert_eq!(j.get("name").and_then(Json::as_str), Some("query"));
        assert_eq!(j.get("partitions").and_then(Json::as_usize), Some(5));
        let children = j.get("children").and_then(Json::as_arr).expect("children");
        assert_eq!(children.len(), 1);
        assert_eq!(children[0].get("considered").and_then(Json::as_usize), Some(7));
    }

    fn entry(secs: f64) -> SlowEntry {
        SlowEntry { secs, op: "stats", trace: Json::Null, explain: Json::Null }
    }

    #[test]
    fn slow_log_keeps_the_worst() {
        let log = SlowQueryLog::new(3);
        for secs in [0.1, 0.5, 0.2, 0.9, 0.05, 0.3] {
            log.offer(entry(secs));
        }
        let kept: Vec<f64> = log.snapshot().iter().map(|e| e.secs).collect();
        assert_eq!(kept, vec![0.9, 0.5, 0.3]);
        let j = log.to_json().to_string();
        assert!(j.contains("\"op\":\"stats\""));
    }

    #[test]
    fn slow_log_is_bounded() {
        let log = SlowQueryLog::default();
        for i in 0..100 {
            log.offer(entry(i as f64));
        }
        let kept = log.snapshot();
        assert_eq!(kept.len(), SLOW_LOG_CAPACITY);
        assert_eq!(kept[0].secs, 99.0);
    }
}
