//! Session drivers: the interactive multi-phase workload that produces the
//! paper's Fig 4 / Fig 6 series, and the planned multi-query batch session
//! (many users' selective queries served through one cluster pass each).

use crate::analysis::{PeriodSpec, PeriodStats};
use crate::coordinator::planner::{IndexKind, Method};
use crate::coordinator::Coordinator;
use crate::engine::{CounterSnapshot, Dataset};
use crate::error::{OsebaError, Result};
use crate::index::RangeQuery;
use crate::metrics::{BatchReport, SessionMetrics, Timer};

/// Everything a session run produces.
#[derive(Clone, Debug)]
pub struct SessionReport {
    /// Which access path the session used.
    pub method: Method,
    /// Per-phase measurements (the Fig 4 / Fig 6 series).
    pub metrics: SessionMetrics,
    /// Per-phase analysis results, in phase order.
    pub stats: Vec<PeriodStats>,
    /// Queries actually executed (resolved from the period specs).
    pub queries: Vec<crate::index::RangeQuery>,
    /// Index metadata footprint (0 for the default method).
    pub index_bytes: usize,
}

/// Run an interactive session: each period in `periods` is one phase of
/// max/mean/std analysis on `column` (paper §IV-A). For
/// [`Method::Default`], filtered datasets stay cached across phases unless
/// `unpersist_filtered` is set (that flag is the "free-filtered" ablation
/// arm — *not* Spark's default).
pub fn run_session(
    coord: &Coordinator,
    ds: &Dataset,
    method: Method,
    index_kind: IndexKind,
    periods: &[PeriodSpec],
    column: usize,
    unpersist_filtered: bool,
) -> Result<SessionReport> {
    let (Some(key_min), Some(key_max)) = (ds.key_min(), ds.key_max()) else {
        return Err(OsebaError::InvalidRange("session over an empty dataset".into()));
    };

    // Index construction happens once, at load time (its cost is part of
    // phase 1's measurement in the paper's framing; here we time it
    // separately into phase 1).
    let build_timer = Timer::start();
    let index = match method {
        Method::Oseba => Some(coord.build_index(ds, index_kind)?),
        Method::Default => None,
    };
    let build_secs = build_timer.secs();
    let index_bytes = index.as_ref().map(|i| i.memory_bytes()).unwrap_or(0);

    let mut metrics = SessionMetrics::new();
    let mut stats = Vec::with_capacity(periods.len());
    let mut queries = Vec::with_capacity(periods.len());

    for (i, spec) in periods.iter().enumerate() {
        let q = spec.resolve(key_min, key_max)?;
        queries.push(q);
        let before = coord.context().counters();
        let timer = Timer::start();
        let st = match (&index, method) {
            (Some(ix), Method::Oseba) => {
                coord.analyze_period_oseba(ds, ix.as_ref(), q, column)?
            }
            (_, Method::Default) => {
                let (st, filtered) = coord.analyze_period_default(ds, q, column)?;
                if unpersist_filtered {
                    coord.context().unpersist(&filtered);
                }
                st
            }
            _ => {
                return Err(OsebaError::Runtime(
                    "session index missing for the Oseba method".into(),
                ))
            }
        };
        let mut secs = timer.secs();
        if i == 0 {
            secs += build_secs;
        }
        stats.push(st);
        metrics.record(
            i + 1,
            method.label(),
            secs,
            coord.context().memory_used(),
            before,
            coord.context().counters(),
        );
    }

    Ok(SessionReport { method, metrics, stats, queries, index_bytes })
}

/// Everything a planned multi-query batch session produces.
#[derive(Clone, Debug)]
pub struct BatchSessionReport {
    /// Per-input-query statistics, in input order.
    pub stats: Vec<PeriodStats>,
    /// Planner/execution counters for the batch.
    pub report: BatchReport,
    /// Index metadata footprint.
    pub index_bytes: usize,
    /// Engine counters sampled just before the batch.
    pub counters_before: CounterSnapshot,
    /// Engine counters sampled just after the batch.
    pub counters_after: CounterSnapshot,
}

/// Run one planned batch session: build the index, plan + execute the
/// whole query batch through [`Coordinator::analyze_batch_with_report`],
/// and capture the engine counters around it. This is the multi-user
/// serving shape: N sessions' queries arrive together and share one
/// cluster pass per merged range.
pub fn run_batch_session(
    coord: &Coordinator,
    ds: &Dataset,
    index_kind: IndexKind,
    queries: &[RangeQuery],
    column: usize,
) -> Result<BatchSessionReport> {
    let index = coord.build_index(ds, index_kind)?;
    let counters_before = coord.context().counters();
    let (stats, report) =
        coord.analyze_batch_with_report(ds, index.as_ref(), queries, column)?;
    let counters_after = coord.context().counters();
    Ok(BatchSessionReport {
        stats,
        report,
        index_bytes: index.memory_bytes(),
        counters_before,
        counters_after,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::five_periods;
    use crate::config::{AppConfig, ContextConfig};
    use crate::datagen::ClimateGen;
    use crate::runtime::NativeBackend;
    use std::sync::Arc;

    fn coord() -> Coordinator {
        let cfg = AppConfig {
            ctx: ContextConfig { num_workers: 4, memory_budget: None },
            cluster_workers: 3,
            ..Default::default()
        };
        Coordinator::new(&cfg, Arc::new(NativeBackend)).unwrap()
    }

    #[test]
    fn five_phase_session_reproduces_figure_shapes() {
        let c = coord();
        let ds = c.load(ClimateGen::default().generate(60_000), 15).unwrap();
        let periods = five_periods();

        let oseba =
            run_session(&c, &ds, Method::Oseba, IndexKind::Cias, &periods, 0, false).unwrap();

        let c2 = coord();
        let ds2 = c2.load(ClimateGen::default().generate(60_000), 15).unwrap();
        let default =
            run_session(&c2, &ds2, Method::Default, IndexKind::Cias, &periods, 0, false)
                .unwrap();

        // Identical analysis results.
        assert_eq!(oseba.stats.len(), 5);
        for (a, b) in oseba.stats.iter().zip(&default.stats) {
            assert_eq!(a.count, b.count);
            assert_eq!(a.max, b.max);
            assert!((a.mean - b.mean).abs() < 1e-6);
        }

        // Fig 4 shape: default memory strictly grows each phase; Oseba flat.
        let dm = default.metrics.memory_series();
        assert!(dm.windows(2).all(|w| w[1] > w[0]), "default memory grows: {dm:?}");
        let om = oseba.metrics.memory_series();
        assert!(om.windows(2).all(|w| w[0] == w[1]), "oseba memory flat: {om:?}");
        assert!(dm[4] > om[4], "default ends higher");

        // Fig 6 signal: default scans all partitions every phase; Oseba
        // targets only intersecting ones.
        for r in &default.metrics.records {
            assert_eq!(r.partitions_scanned, 15);
            assert!(r.bytes_materialized > 0);
        }
        for r in &oseba.metrics.records {
            assert_eq!(r.partitions_scanned, 0);
            assert!(r.partitions_targeted < 15);
            assert_eq!(r.bytes_materialized, 0);
        }

        assert!(oseba.index_bytes > 0);
        assert_eq!(default.index_bytes, 0);
        assert_eq!(oseba.queries, default.queries);
    }

    #[test]
    fn batch_session_reports_counters_and_stats() {
        let c = coord();
        let ds = c.load(ClimateGen::default().generate(30_000), 10).unwrap();
        let h = 3600i64;
        let queries = vec![
            crate::index::RangeQuery { lo: 0, hi: 6_000 * h },
            crate::index::RangeQuery { lo: 4_000 * h, hi: 9_000 * h },
            crate::index::RangeQuery { lo: 20_000 * h, hi: 24_000 * h },
        ];
        let rep = run_batch_session(&c, &ds, IndexKind::Cias, &queries, 0).unwrap();
        assert_eq!(rep.stats.len(), 3);
        assert_eq!(rep.report.queries, 3);
        assert_eq!(rep.report.merged_ranges, 2, "first two overlap");
        assert!(rep.index_bytes > 0);
        // The batch is pure index-path work: no scans, some targeting.
        assert_eq!(
            rep.counters_after.partitions_scanned,
            rep.counters_before.partitions_scanned
        );
        assert!(rep.counters_after.partitions_targeted > rep.counters_before.partitions_targeted);
        assert_eq!(
            rep.counters_after.partitions_targeted - rep.counters_before.partitions_targeted,
            rep.report.partitions_touched
        );
    }

    #[test]
    fn unpersist_ablation_keeps_memory_flat() {
        let c = coord();
        let ds = c.load(ClimateGen::default().generate(30_000), 10).unwrap();
        let report =
            run_session(&c, &ds, Method::Default, IndexKind::Cias, &five_periods(), 0, true)
                .unwrap();
        let mem = report.metrics.memory_series();
        // Memory returns to the raw-data baseline after each phase.
        assert!(mem.windows(2).all(|w| w[0] == w[1]), "{mem:?}");
    }
}
