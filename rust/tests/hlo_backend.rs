//! Integration: the AOT/PJRT path end-to-end.
//!
//! Loads the real `artifacts/` produced by `make artifacts`, spawns the
//! kernel service, and checks every kernel against the native backend on
//! randomized blocks — the rust-side mirror of `python/tests/test_kernels.py`
//! (which checks pallas vs the jnp oracle; here we check the *compiled HLO*
//! vs the rust oracle, closing the loop).

use std::sync::Arc;

use oseba::runtime::{spawn_kernel_service, AnalysisBackend, NativeBackend};
use oseba::storage::BLOCK_ROWS;
use oseba::util::rng::Xoshiro256;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

fn hlo() -> oseba::runtime::KernelHandle {
    spawn_kernel_service(artifacts_dir(), false).expect("kernel service")
}

fn rand_block(rng: &mut Xoshiro256) -> Vec<f32> {
    (0..BLOCK_ROWS).map(|_| (rng.next_f32() * 2.0 - 1.0) * 100.0).collect()
}

#[test]
fn segment_stats_hlo_matches_native() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    }
    let h = hlo();
    let n = NativeBackend;
    let mut rng = Xoshiro256::seeded(101);
    for case in 0..12 {
        let block = rand_block(&mut rng);
        let (s, e) = match case {
            0 => (0, BLOCK_ROWS),
            1 => (17, 17), // empty
            2 => (BLOCK_ROWS - 1, BLOCK_ROWS),
            _ => {
                let a = rng.below(BLOCK_ROWS as u64) as usize;
                let b = rng.below(BLOCK_ROWS as u64) as usize;
                (a.min(b), a.max(b))
            }
        };
        let got = h.segment_stats(&block, s, e).unwrap();
        let want = n.segment_stats(&block, s, e).unwrap();
        assert_eq!(got.count, want.count, "case {case}");
        assert_eq!(got.max, want.max, "case {case}");
        assert_eq!(got.min, want.min, "case {case}");
        assert!((got.sum - want.sum).abs() < 0.5, "case {case}: {} vs {}", got.sum, want.sum);
        assert!(
            (got.sumsq - want.sumsq).abs() / want.sumsq.max(1.0) < 1e-3,
            "case {case}"
        );
    }
}

#[test]
fn moving_average_hlo_matches_native() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let h = hlo();
    let n = NativeBackend;
    let mut rng = Xoshiro256::seeded(202);
    for &w in &[4usize, 16, 64] {
        let block = rand_block(&mut rng);
        let (s, e) = (100, 3000);
        let got = h.moving_average(&block, s, e, w).unwrap();
        let want = n.moving_average(&block, s, e, w).unwrap();
        assert_eq!(got.len(), want.len());
        for i in 0..got.len() {
            assert!(
                (got[i] - want[i]).abs() < 1e-2,
                "w={w} i={i}: {} vs {}",
                got[i],
                want[i]
            );
        }
    }
}

#[test]
fn ma_stats_hlo_matches_native() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let h = hlo();
    let n = NativeBackend;
    let mut rng = Xoshiro256::seeded(303);
    let block = rand_block(&mut rng);
    for &w in &[4usize, 16] {
        let got = h.ma_stats(&block, 50, 4000, w).unwrap();
        let want = n.ma_stats(&block, 50, 4000, w).unwrap();
        assert_eq!(got.count, want.count, "w={w}");
        assert!((got.max - want.max).abs() < 1e-3, "w={w}");
        assert!((got.mean() - want.mean()).abs() < 1e-3, "w={w}");
        assert!((got.std() - want.std()).abs() < 1e-2, "w={w}");
    }
    // Non-AOT window is a clean error, not a wrong answer.
    assert!(h.ma_stats(&block, 0, 100, 5).is_err());
}

#[test]
fn distance_hlo_matches_native() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let h = hlo();
    let n = NativeBackend;
    let mut rng = Xoshiro256::seeded(404);
    let a = rand_block(&mut rng);
    let b = rand_block(&mut rng);
    for (s, e) in [(0, BLOCK_ROWS), (1000, 1000), (123, 3877)] {
        let got = h.distance(&a, &b, s, e).unwrap();
        let want = n.distance(&a, &b, s, e).unwrap();
        assert_eq!(got.count, want.count);
        assert_eq!(got.linf, want.linf);
        assert!((got.l1 - want.l1).abs() < 0.5);
        assert!((got.l2sq - want.l2sq).abs() / want.l2sq.max(1.0) < 1e-3);
    }
}

#[test]
fn histogram_hlo_matches_native_exactly() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let h = hlo();
    let n = NativeBackend;
    let mut rng = Xoshiro256::seeded(505);
    let block = rand_block(&mut rng);
    for (s, e, lo, hi) in [(0, BLOCK_ROWS, -100.0f32, 100.0f32), (500, 2500, -10.0, 10.0)] {
        let got = h.histogram64(&block, s, e, lo, hi).unwrap();
        let want = n.histogram64(&block, s, e, lo, hi).unwrap();
        assert_eq!(got, want, "[{lo},{hi}) rows {s}..{e}");
        assert_eq!(got.iter().sum::<f32>() as usize, e - s);
    }
}

#[test]
fn batch_api_matches_singles_and_counts_service_stats() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let h = hlo();
    let mut rng = Xoshiro256::seeded(606);
    let blocks: Vec<Vec<f32>> = (0..4).map(|_| rand_block(&mut rng)).collect();
    let reqs: Vec<(&[f32], usize, usize)> =
        blocks.iter().map(|b| (b.as_slice(), 10, 4000)).collect();
    let batch = h.segment_stats_batch(&reqs).unwrap();
    for (i, b) in blocks.iter().enumerate() {
        let single = h.segment_stats(b, 10, 4000).unwrap();
        assert_eq!(batch[i], single, "block {i}");
    }
    let stats = h.service_stats().unwrap();
    // The batch of 4 rides the packing policy (one grid execution, or 4
    // singles when padding waste would exceed the policy threshold); the 4
    // explicit singles are one execution each.
    assert!(
        (5..=8).contains(&stats.executions),
        "between 1 grid + 4 singles and 8 singles expected: {}",
        stats.executions
    );
    assert!(stats.requests >= 5);
    assert!(stats.busy_secs > 0.0);
}

#[test]
fn wrong_block_length_rejected() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let h = hlo();
    assert!(h.segment_stats(&[0.0; 128], 0, 128).is_err());
}

#[test]
fn handle_is_shareable_across_threads() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let h = Arc::new(hlo());
    let mut rng = Xoshiro256::seeded(707);
    let block = Arc::new(rand_block(&mut rng));
    let expected = h.segment_stats(&block, 0, BLOCK_ROWS).unwrap();
    std::thread::scope(|s| {
        for _ in 0..4 {
            let h = Arc::clone(&h);
            let block = Arc::clone(&block);
            s.spawn(move || {
                for _ in 0..5 {
                    let got = h.segment_stats(&block, 0, BLOCK_ROWS).unwrap();
                    assert_eq!(got, expected);
                }
            });
        }
    });
}
