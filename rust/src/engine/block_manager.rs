//! The block manager: registry of cached (memory-resident) datasets.
//!
//! Mirrors Spark's BlockManager at the granularity this reproduction
//! needs: datasets cache their partitions here, bytes are charged to the
//! [`MemoryTracker`], and `unpersist` releases them. The Fig 4 "default
//! method" curve is exactly this registry filling up with filter-RDDs.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::engine::memory::MemoryTracker;
use crate::error::{OsebaError, Result};
use crate::storage::Partition;

/// Identifier of a cached dataset.
pub type DatasetId = u64;

#[derive(Debug)]
struct CacheEntry {
    parts: Vec<Arc<Partition>>,
    bytes: usize,
}

/// Thread-safe cached-dataset registry with byte accounting.
#[derive(Debug)]
pub struct BlockManager {
    tracker: Arc<MemoryTracker>,
    cache: Mutex<HashMap<DatasetId, CacheEntry>>,
}

impl BlockManager {
    pub fn new(tracker: Arc<MemoryTracker>) -> BlockManager {
        BlockManager { tracker, cache: Mutex::new(HashMap::new()) }
    }

    /// Cache a dataset's partitions, charging their bytes.
    pub fn cache(&self, id: DatasetId, parts: Vec<Arc<Partition>>) -> Result<()> {
        let bytes: usize = parts.iter().map(|p| p.bytes()).sum();
        let mut cache = self.cache.lock().unwrap();
        if cache.contains_key(&id) {
            return Err(OsebaError::Schema(format!("dataset {id} already cached")));
        }
        self.tracker.allocate(bytes)?;
        cache.insert(id, CacheEntry { parts, bytes });
        Ok(())
    }

    /// Fetch a cached dataset's partitions.
    pub fn get(&self, id: DatasetId) -> Option<Vec<Arc<Partition>>> {
        self.cache.lock().unwrap().get(&id).map(|e| e.parts.clone())
    }

    /// Evict a dataset, crediting its bytes. Returns whether it was cached.
    pub fn unpersist(&self, id: DatasetId) -> bool {
        let entry = self.cache.lock().unwrap().remove(&id);
        match entry {
            Some(e) => {
                self.tracker.release(e.bytes);
                true
            }
            None => false,
        }
    }

    /// Total bytes currently cached.
    pub fn used_bytes(&self) -> usize {
        self.tracker.used()
    }

    /// High-water mark of cached bytes.
    pub fn peak_bytes(&self) -> usize {
        self.tracker.peak()
    }

    /// Number of cached datasets.
    pub fn num_cached(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// The shared tracker (for coordinator metrics).
    pub fn tracker(&self) -> Arc<MemoryTracker> {
        Arc::clone(&self.tracker)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::{BatchBuilder, Schema};

    fn one_part(rows: usize) -> Vec<Arc<Partition>> {
        let mut b = BatchBuilder::new(Schema::stock());
        for i in 0..rows {
            b.push(i as i64, &[0.0, 0.0]);
        }
        crate::storage::partition_batch(&b.finish().unwrap(), 1).unwrap()
    }

    #[test]
    fn cache_charges_and_unpersist_credits() {
        let bm = BlockManager::new(MemoryTracker::unbounded());
        let parts = one_part(100);
        let bytes: usize = parts.iter().map(|p| p.bytes()).sum();
        bm.cache(1, parts).unwrap();
        assert_eq!(bm.used_bytes(), bytes);
        assert_eq!(bm.num_cached(), 1);
        assert!(bm.unpersist(1));
        assert_eq!(bm.used_bytes(), 0);
        assert!(!bm.unpersist(1));
    }

    #[test]
    fn duplicate_cache_rejected() {
        let bm = BlockManager::new(MemoryTracker::unbounded());
        bm.cache(7, one_part(10)).unwrap();
        assert!(bm.cache(7, one_part(10)).is_err());
    }

    #[test]
    fn get_returns_same_partitions() {
        let bm = BlockManager::new(MemoryTracker::unbounded());
        let parts = one_part(10);
        bm.cache(3, parts.clone()).unwrap();
        let got = bm.get(3).unwrap();
        assert_eq!(got.len(), parts.len());
        assert!(Arc::ptr_eq(&got[0], &parts[0]));
        assert!(bm.get(99).is_none());
    }

    #[test]
    fn budget_propagates_to_cache() {
        let bm = BlockManager::new(MemoryTracker::with_budget(10));
        assert!(bm.cache(1, one_part(100)).is_err());
        assert_eq!(bm.num_cached(), 0);
        assert_eq!(bm.used_bytes(), 0);
    }
}
