//! Seeded violation: raw filesystem calls inside `store/` that bypass
//! the failpoint-instrumented `StoreIo` wrapper in `store/fault.rs`.

use std::fs::File;

pub fn read_segment(path: &std::path::Path) -> std::io::Result<Vec<u8>> {
    std::fs::read(path)
}

pub fn open_segment(path: &std::path::Path) -> std::io::Result<File> {
    File::open(path)
}

pub fn truncate_segment(path: &std::path::Path) -> std::io::Result<File> {
    // lint: allow(store-io-wrapped)
    File::create(path)
}
