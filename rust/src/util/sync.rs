//! Poison-tolerant locking helpers.
//!
//! The engine's shared state (store tiers, block cache, live datasets,
//! cluster registries, pool queues) is guarded by `std` mutexes. A panic on
//! one thread while a guard is held poisons the mutex, and the default
//! `lock().unwrap()` idiom then cascades that one failure into a panic in
//! every other thread that touches the lock — a poisoned block cache would
//! take down the whole server even though the cached bytes are still valid.
//!
//! All of this crate's critical sections either complete their updates
//! before any fallible call or protect plain data whose worst case after an
//! interrupted update is a stale-but-well-formed value (cache maps, counter
//! structs, queues of owned jobs). Recovering the guard is therefore sound,
//! and strictly better than propagating the panic: the first panic is still
//! reported (the server catches it at the session boundary and returns a
//! typed error), while unrelated sessions keep working.
//!
//! `oseba-lint` (`tools/lint`) bans `lock().unwrap()` tree-wide; these
//! helpers are the sanctioned replacement.

use std::sync::{Mutex, MutexGuard, PoisonError, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// Recover the guard from a possibly-poisoned lock result.
///
/// Works for `Mutex::lock`, `RwLock::read`/`write`, and `Condvar::wait`
/// results alike, since all of them wrap their guard in `PoisonError`.
pub fn recover<G>(r: Result<G, PoisonError<G>>) -> G {
    r.unwrap_or_else(PoisonError::into_inner)
}

/// `Mutex` extension: lock and recover from poisoning in one call.
pub trait MutexExt<T: ?Sized> {
    /// Like `lock().unwrap()` but recovers the guard if the mutex was
    /// poisoned by a panicking thread instead of propagating the panic.
    fn lock_recover(&self) -> MutexGuard<'_, T>;
}

impl<T: ?Sized> MutexExt<T> for Mutex<T> {
    fn lock_recover(&self) -> MutexGuard<'_, T> {
        recover(self.lock())
    }
}

/// `RwLock` extension: acquire and recover from poisoning in one call.
pub trait RwLockExt<T: ?Sized> {
    /// Poison-tolerant `read().unwrap()`.
    fn read_recover(&self) -> RwLockReadGuard<'_, T>;
    /// Poison-tolerant `write().unwrap()`.
    fn write_recover(&self) -> RwLockWriteGuard<'_, T>;
}

impl<T: ?Sized> RwLockExt<T> for RwLock<T> {
    fn read_recover(&self) -> RwLockReadGuard<'_, T> {
        recover(self.read())
    }

    fn write_recover(&self) -> RwLockWriteGuard<'_, T> {
        recover(self.write())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn poison<T: Send + 'static>(m: &Arc<Mutex<T>>) {
        let m2 = Arc::clone(m);
        let h = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("deliberate poison");
        });
        assert!(h.join().is_err());
        assert!(m.is_poisoned());
    }

    #[test]
    fn lock_recover_returns_guard_after_poison() {
        let m = Arc::new(Mutex::new(41));
        poison(&m);
        *m.lock_recover() += 1;
        assert_eq!(*m.lock_recover(), 42);
    }

    #[test]
    fn rwlock_recover_after_poison() {
        let l = Arc::new(RwLock::new(vec![1, 2, 3]));
        let l2 = Arc::clone(&l);
        let h = std::thread::spawn(move || {
            let _g = l2.write().unwrap();
            panic!("deliberate poison");
        });
        assert!(h.join().is_err());
        l.write_recover().push(4);
        assert_eq!(*l.read_recover(), vec![1, 2, 3, 4]);
    }
}
